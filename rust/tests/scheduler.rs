//! Continuous-batching scheduler suite.
//!
//! The load-bearing claim: a request stepped through the scheduler —
//! interleaved poll-by-poll with other in-flight requests on the same
//! engine — produces **bit-identical** text and metrics to the
//! pre-refactor blocking loop (`run_method` drives the same `Driver`
//! state machine to completion solo). Per-request `GenState` isolation
//! is what makes interleaving invisible; these tests pin it for all
//! four methods.
//!
//! Artifact-gated tests skip (loudly) when `artifacts/` is absent —
//! always the case under the offline xla stub. The scheduler policy
//! itself (admission, refill-after-prune, out-of-order completion,
//! shutdown draining) is covered without artifacts by the in-module
//! tests in `src/server/mod.rs`, which drive the same `scheduler_loop`
//! with synthetic drivers.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;
use kappa::coordinator::config::{Method, RunConfig};
use kappa::coordinator::{
    make_driver, make_driver_fused, run_method, Driver, GenOutput, StepOutcome, StepPlan,
};
use kappa::data::Dataset;
use kappa::engine::{Engine, FuseConfig, FusionHub, PodFault};
use kappa::runtime::{FaultError, FaultPlan, FaultSite, LoadedModel, Manifest, Runtime};
use kappa::server::{request_seed, Pollable, SchedConfig, Scheduler, Server};
use kappa::util::rng::Pcg64;

fn artifacts_dir() -> String {
    std::env::var("KAPPA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn load() -> Option<Arc<Engine>> {
    let manifest = match Manifest::load(artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP (no artifacts — run `make artifacts`): {e:#}");
            return None;
        }
    };
    let rt = Arc::new(Runtime::new().expect("pjrt client"));
    let model = LoadedModel::load(rt, &manifest, "sm").expect("load sm");
    Some(Arc::new(Engine::new(Arc::new(model))))
}

fn assert_outputs_identical(a: &GenOutput, b: &GenOutput, what: &str) {
    assert_eq!(a.text, b.text, "{what}: text");
    assert_eq!(a.chosen_branch, b.chosen_branch, "{what}: chosen branch");
    assert_eq!(a.metrics.final_branch_tokens, b.metrics.final_branch_tokens, "{what}: tokens");
    assert_eq!(a.metrics.total_tokens, b.metrics.total_tokens, "{what}: total tokens");
    assert_eq!(a.metrics.peak_mem_bytes, b.metrics.peak_mem_bytes, "{what}: peak mem");
    assert_eq!(a.metrics.decode_calls, b.metrics.decode_calls, "{what}: decode calls");
    assert_eq!(a.metrics.gather_calls, b.metrics.gather_calls, "{what}: gather calls");
}

/// Scheduler-stepped requests are bit-identical to blocking runs, for
/// every method: three requests are interleaved poll-by-poll on one
/// engine (exactly what the worker's round-robin tick does) and each
/// result compared against its solo `run_method` twin.
#[test]
fn interleaved_driver_stepping_is_bit_identical_to_blocking_runs() {
    let Some(engine) = load() else { return };
    let problems = Dataset::GsmSynth.generate(3, 77);

    for method in [Method::Greedy, Method::Bon, Method::StBon, Method::Kappa] {
        let cfg = RunConfig { method, n: 4, max_new_tokens: 48, ..RunConfig::default() };

        // Blocking oracle: each request solo, in order.
        let blocking: Vec<GenOutput> = problems
            .iter()
            .enumerate()
            .map(|(i, p)| {
                run_method(&engine, &p.prompt(), &cfg, request_seed(5, i as u64)).expect("blocking")
            })
            .collect();

        // Scheduler shape: all three in flight at once, round-robin
        // polled until each completes (out of order is fine — results
        // are keyed by request index).
        let mut drivers: Vec<_> = problems
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Some(make_driver(&engine, &p.prompt(), &cfg, request_seed(5, i as u64)).unwrap())
            })
            .collect();
        let mut stepped: Vec<Option<GenOutput>> = vec![None, None, None];
        while stepped.iter().any(|o| o.is_none()) {
            for (i, slot) in drivers.iter_mut().enumerate() {
                let Some(driver) = slot else { continue };
                match driver.poll_step(&engine).expect("poll") {
                    StepOutcome::Pending => {}
                    StepOutcome::Done(out) => {
                        stepped[i] = Some(out);
                        *slot = None;
                    }
                }
            }
        }

        for (i, (b, s)) in blocking.iter().zip(&stepped).enumerate() {
            let s = s.as_ref().unwrap();
            assert_outputs_identical(b, s, &format!("{method:?} request {i}"));
        }
    }
}

/// Occupancy reporting: a KAPPA request's device slots shrink as gating
/// prunes branches — the signal the scheduler's admission control reads.
#[test]
fn driver_occupancy_shrinks_as_pruning_frees_slots() {
    let Some(engine) = load() else { return };
    let problems = Dataset::GsmSynth.generate(1, 13);
    let cfg = RunConfig { method: Method::Kappa, n: 4, max_new_tokens: 48, ..RunConfig::default() };
    let mut driver = make_driver(&engine, &problems[0].prompt(), &cfg, 3).unwrap();

    let initial = driver.device_slots();
    assert!(initial >= 4, "4-branch request must start in a ≥4 bucket");
    let mut min_slots = initial;
    loop {
        match driver.poll_step(&engine).expect("poll") {
            StepOutcome::Pending => min_slots = min_slots.min(driver.device_slots()),
            StepOutcome::Done(_) => break,
        }
    }
    assert!(
        min_slots < initial,
        "gating never freed a slot (started at {initial}, never dropped)"
    );
}

/// Many requests / few workers through the real server: every response
/// arrives, and the continuous-batching worker reports >1 in-flight
/// occupancy while the queue is backed up.
#[test]
fn server_schedules_many_requests_onto_few_workers() {
    if !std::path::Path::new(&format!("{}/manifest.json", artifacts_dir())).exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = RunConfig { method: Method::Kappa, n: 4, max_new_tokens: 48, ..RunConfig::default() };
    let sched =
        SchedConfig { max_inflight: 4, slot_budget: 32, fuse: true, ..SchedConfig::default() };
    let server = Server::start_with(&artifacts_dir(), "sm", 1, cfg, sched).expect("boot");

    let problems = Dataset::GsmSynth.generate(8, 41);
    let prompts: Vec<String> = problems.iter().map(|p| p.prompt()).collect();
    let responses = server.submit_all(&prompts, 9);

    assert_eq!(responses.len(), 8);
    let mut max_inflight = 0usize;
    for resp in &responses {
        let r = resp.as_ref().expect("response ok");
        assert!(r.output.metrics.total_tokens > 0);
        max_inflight = max_inflight.max(r.inflight);
    }
    assert!(
        max_inflight > 1,
        "8 queued requests on one worker never overlapped (max inflight {max_inflight})"
    );
    server.shutdown();
}

// ---- cross-request batch fusion (PR 4) ----

fn packed_ready(engine: &Engine) -> bool {
    engine.model().buckets().iter().all(|&b| engine.model().has_packed(b))
}

/// Fused in-flight request for driving the scheduler core directly:
/// plan/absorb through the driver, the pod flush supplying the dispatch
/// (the same phasing the server worker runs).
struct FusedFlight<'e> {
    driver: Box<dyn Driver>,
    engine: &'e Engine,
}

impl Pollable for FusedFlight<'_> {
    fn plan(&mut self) -> Result<StepPlan> {
        self.driver.plan_step(self.engine)
    }
    fn absorb(&mut self) -> Result<StepOutcome> {
        self.driver.absorb_step(self.engine)
    }
    fn device_slots(&self) -> usize {
        self.driver.device_slots()
    }
    fn mem_bytes(&self) -> usize {
        self.driver.mem_bytes()
    }
}

/// Run `prompts` through the fused scheduler core. Admission follows
/// `order` (indices into `prompts`) with a seeded coin flip per tick, so
/// requests join pods at arbitrary phases of their pod-mates' lives;
/// per-request seeds stay keyed to the *original* index, so the same
/// request draws the same RNG streams whatever the packing. When
/// `compact` is set the trace runs the pod-compaction pass between
/// ticks (the worker loop's shape) and asserts every committed
/// compaction physically shrinks `FusionHub::pod_bytes` while the pod
/// stays occupied. When `overlap` is set the trace runs the
/// software-pipelined tick (PR 9) — `FusionHub::issue` launches every
/// occupied pod's dispatch, the absorb phase demand-awaits, and
/// `FusionHub::await_ready` drains the tickets at end of tick — instead
/// of the synchronous flush oracle. `evict_at_tick` drops the
/// youngest in-flight request's driver mid-flight at the first
/// eligible tick and requeues it (the fused evict/re-admit round
/// trip); the eviction happens between ticks, where every pod is
/// quiescent. Returns outputs indexed by original position plus the
/// hub's stats.
#[allow(clippy::too_many_arguments)]
fn run_fused_trace_with(
    engine: &Engine,
    fuse_cfg: FuseConfig,
    compact: bool,
    overlap: bool,
    evict_at_tick: Option<usize>,
    prompts: &[String],
    cfg: &RunConfig,
    seed0: u64,
    order: &[usize],
    admit_seed: u64,
    max_inflight: usize,
) -> (Vec<GenOutput>, kappa::engine::FuseStats) {
    let hub = FusionHub::new(fuse_cfg);
    let sched_cfg =
        SchedConfig { max_inflight, slot_budget: 32, fuse: true, ..SchedConfig::default() };
    let mut sched: Scheduler<FusedFlight, usize> = Scheduler::new(sched_cfg);
    let admission = engine.admission_cost(cfg.concurrent_branches()).expect("admission cost");
    let mut admit_rng = Pcg64::new(admit_seed, 1);
    let mut queue: VecDeque<usize> = order.iter().copied().collect();
    let mut out: Vec<Option<GenOutput>> = (0..prompts.len()).map(|_| None).collect();
    let dispatches_before = engine.model().runtime().decode_dispatch_count();
    let mut ticks = 0usize;
    let mut evicted = false;
    while !(queue.is_empty() && sched.is_empty()) {
        ticks += 1;
        assert!(ticks < 100_000, "fused trace runaway");
        if let Some(evict_at) = evict_at_tick {
            // Between ticks every pod is quiescent (the overlapped tick
            // ends with a hub drain), so dropping a driver here never
            // abandons an in-flight ticket.
            if !evicted && ticks >= evict_at && sched.len() > 1 {
                let (flight, i) = sched.evict_youngest(|_| true).expect("evictable");
                drop(flight); // releases the pod lease on the spot
                queue.push_back(i);
                evicted = true;
            }
        }
        if compact {
            // Between ticks every pod is quiescent — the worker loop's
            // compaction point. A committed compaction must be a real
            // physical reclaim, visible in the hub's tracker while the
            // pod is still occupied.
            let before = hub.pod_bytes();
            let reclaimed = hub.maybe_compact(engine, false).expect("pod compaction");
            if reclaimed > 0 {
                assert!(hub.pod_count() > 0, "compaction only runs on occupied pods");
                // The pass may also retire pods that emptied since the
                // last tick, so the drop is *at least* the reported
                // reclaim — and strictly below the pre-pass residency.
                assert!(
                    hub.pod_bytes() + reclaimed <= before,
                    "compaction must shrink physical pod bytes by at least what it reports \
                     ({before} -> {}, reported {reclaimed})",
                    hub.pod_bytes()
                );
            }
        }
        while !queue.is_empty()
            && sched.can_admit(admission.0, admission.1)
            && admit_rng.below(4) != 0
        {
            let i = queue.pop_front().unwrap();
            let driver =
                make_driver_fused(engine, &hub, &prompts[i], cfg, request_seed(seed0, i as u64))
                    .expect("fused driver");
            sched.admit(FusedFlight { driver, engine }, i);
        }
        let on_done = |i: usize, r: Result<GenOutput>| {
            out[i] = Some(r.expect("fused request failed"));
        };
        if overlap {
            sched.tick_overlapped(|| hub.issue(engine), || hub.await_ready(), on_done);
        } else {
            sched.tick(|| hub.flush(engine), on_done);
        }
    }
    if evict_at_tick.is_some() {
        assert!(evicted, "the trace never reached an evictable state — it exercised nothing");
    }
    // The fused invariant while we are here, across two independent
    // counters: every decode-family dispatch of the trace came from a
    // pod flush, exactly one per occupied pod per tick (the Runtime
    // counts dispatches at the execute sites; the hub counts pods with
    // staged work before each flush). Compaction dispatches count on
    // their own Runtime counter and must not perturb this equality.
    let dispatched = engine.model().runtime().decode_dispatch_count() - dispatches_before;
    assert_eq!(
        dispatched,
        hub.stats().occupied_pod_ticks,
        "fused trace issued {dispatched} decode dispatches across {} occupied pod-ticks",
        hub.stats().occupied_pod_ticks
    );
    let stats = hub.stats();
    (out.into_iter().map(|o| o.expect("request never completed")).collect(), stats)
}

/// [`run_fused_trace_with`] at the default pod config, no compaction,
/// no eviction — sync or overlapped per `overlap`.
#[allow(clippy::too_many_arguments)]
fn run_fused_trace(
    engine: &Engine,
    prompts: &[String],
    cfg: &RunConfig,
    seed0: u64,
    order: &[usize],
    admit_seed: u64,
    max_inflight: usize,
    overlap: bool,
) -> Vec<GenOutput> {
    run_fused_trace_with(
        engine,
        FuseConfig::default(),
        false,
        overlap,
        None,
        prompts,
        cfg,
        seed0,
        order,
        admit_seed,
        max_inflight,
    )
    .0
}

/// The PR 4 load-bearing claim: a request served through **fused
/// ticks** — its branches packed into shared pod dispatches with other
/// requests, admitted at randomized offsets — produces bit-identical
/// text *and metrics* to its solo blocking run, for all four methods.
#[test]
fn fused_ticks_are_bit_identical_to_blocking_runs_for_all_methods() {
    let Some(engine) = load() else { return };
    if !packed_ready(&engine) {
        eprintln!("SKIP: artifact set has no packed executables (re-run `make artifacts`)");
        return;
    }
    let problems = Dataset::GsmSynth.generate(4, 77);
    let prompts: Vec<String> = problems.iter().map(|p| p.prompt()).collect();
    let order: Vec<usize> = (0..prompts.len()).collect();

    for method in [Method::Greedy, Method::Bon, Method::StBon, Method::Kappa] {
        let cfg = RunConfig { method, n: 4, max_new_tokens: 48, ..RunConfig::default() };
        let blocking: Vec<GenOutput> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| run_method(&engine, p, &cfg, request_seed(5, i as u64)).expect("blocking"))
            .collect();
        // Several randomized admission interleavings: each packs the
        // same requests into pods at different co-residency phases.
        // Every trace runs both tick shapes — the synchronous flush
        // oracle and the software-pipelined issue/await split (PR 9) —
        // and both must match the blocking run bit for bit (text *and*
        // metrics), which also pins them bit-identical to each other.
        for admit_seed in [1u64, 9, 23] {
            for overlap in [false, true] {
                let fused =
                    run_fused_trace(&engine, &prompts, &cfg, 5, &order, admit_seed, 3, overlap);
                for (i, (b, f)) in blocking.iter().zip(&fused).enumerate() {
                    assert_outputs_identical(
                        b,
                        f,
                        &format!(
                            "{method:?} request {i} (admit seed {admit_seed}, overlap {overlap})"
                        ),
                    );
                }
            }
        }
    }
}

/// Satellite property: per-request RNG streams are independent of
/// co-resident packing order — permuting the admission order of *other*
/// requests leaves every request's sampled token trace (and with it the
/// full output) bit-identical.
#[test]
fn request_rng_streams_independent_of_coresident_packing_order() {
    let Some(engine) = load() else { return };
    if !packed_ready(&engine) {
        eprintln!("SKIP: artifact set has no packed executables (re-run `make artifacts`)");
        return;
    }
    let problems = Dataset::GsmSynth.generate(4, 31);
    let prompts: Vec<String> = problems.iter().map(|p| p.prompt()).collect();
    let cfg = RunConfig { method: Method::Kappa, n: 4, max_new_tokens: 48, ..RunConfig::default() };

    // Overlapped ticks (the serving default) — RNG independence must
    // hold with the awaits moved just as it does synchronously.
    let natural = run_fused_trace(&engine, &prompts, &cfg, 13, &[0, 1, 2, 3], 7, 4, true);
    for order in [[2usize, 0, 3, 1], [3, 2, 1, 0], [1, 3, 0, 2]] {
        let permuted = run_fused_trace(&engine, &prompts, &cfg, 13, &order, 7, 4, true);
        for (i, (a, b)) in natural.iter().zip(&permuted).enumerate() {
            assert_outputs_identical(a, b, &format!("request {i} under admission order {order:?}"));
        }
    }
}

/// `shutdown_now` with requests still queued: every pending submission
/// observes an error (directly or by channel drop) and nothing
/// deadlocks or panics.
#[test]
fn server_shutdown_now_fails_queued_requests_without_deadlock() {
    if !std::path::Path::new(&format!("{}/manifest.json", artifacts_dir())).exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = RunConfig { method: Method::Kappa, n: 4, ..RunConfig::default() };
    let sched =
        SchedConfig { max_inflight: 1, slot_budget: 32, fuse: true, ..SchedConfig::default() };
    let server = Server::start_with(&artifacts_dir(), "sm", 1, cfg, sched).expect("boot");

    let problems = Dataset::GsmSynth.generate(6, 51);
    let rxs: Vec<_> = problems
        .iter()
        .enumerate()
        .map(|(i, p)| server.submit(&p.prompt(), request_seed(1, i as u64)).expect("queue open"))
        .collect();
    server.shutdown_now();

    // Each pending request resolves — Ok (finished before the stop flag
    // landed), an explicit Err, or a dropped channel (also a clean
    // failure). None may hang: `recv` returning at all is the assertion.
    for rx in rxs {
        let _ = rx.recv();
    }
}

// ---- pod lifecycle: compaction + eviction (PR 5) ----

fn compact_ready(engine: &Engine) -> bool {
    let m = engine.model();
    let buckets = m.buckets();
    buckets.iter().all(|&s| buckets.iter().filter(|&&d| d < s).all(|&d| m.has_compact(s, d)))
}

/// The PR 5 load-bearing claim: a request that lives through pod
/// compactions — its leased rows physically relocated into smaller pods
/// while it runs — produces bit-identical text *and metrics* to its
/// solo blocking run, for all four methods. The aggressive trigger
/// (streak 1, ratio ~1) forces compaction at every opportunity, so the
/// trace crosses several pod rewrites per request.
#[test]
fn requests_surviving_pod_compaction_are_bit_identical_to_blocking_runs() {
    let Some(engine) = load() else { return };
    if !packed_ready(&engine) || !compact_ready(&engine) {
        eprintln!("SKIP: artifact set has no packed/compact executables (re-run `make artifacts`)");
        return;
    }
    let problems = Dataset::GsmSynth.generate(4, 91);
    let prompts: Vec<String> = problems.iter().map(|p| p.prompt()).collect();
    let order: Vec<usize> = (0..prompts.len()).collect();
    let aggressive = FuseConfig { compact_ratio: 0.99, compact_streak: 1, ..FuseConfig::default() };

    let mut any_compaction = false;
    for method in [Method::Greedy, Method::Bon, Method::StBon, Method::Kappa] {
        let cfg = RunConfig { method, n: 4, max_new_tokens: 48, ..RunConfig::default() };
        let blocking: Vec<GenOutput> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| run_method(&engine, p, &cfg, request_seed(5, i as u64)).expect("blocking"))
            .collect();
        // Compaction × overlap (PR 9): between-ticks compaction only
        // ever sees quiescent pods — the overlapped tick drains every
        // ticket before it ends — so relocating leased rows stays
        // bit-identical with the awaits moved.
        for admit_seed in [1u64, 23] {
            for overlap in [false, true] {
                let (fused, stats) = run_fused_trace_with(
                    &engine, aggressive, true, overlap, None, &prompts, &cfg, 5, &order,
                    admit_seed, 3,
                );
                any_compaction |= stats.compactions > 0;
                for (i, (b, f)) in blocking.iter().zip(&fused).enumerate() {
                    assert_outputs_identical(
                        b,
                        f,
                        &format!(
                            "{method:?} request {i} through compaction \
                             (admit seed {admit_seed}, overlap {overlap})"
                        ),
                    );
                }
            }
        }
    }
    assert!(
        any_compaction,
        "the aggressive trigger never compacted a pod — the test exercised nothing"
    );
}

// ---- fault-domain isolation and deterministic recovery (PR 6) ----

/// Run `prompts` through the fused scheduler core under an installed
/// fault plan, retrying any request failed by a *contained* fault (a
/// [`PodFault`] or [`FaultError`] in its error chain) exactly the way
/// the worker loop does: requeue, fresh driver, same `(prompt, seed)`.
/// Any non-contained error fails the test. Returns outputs indexed by
/// original position, per-request retry and spawn counts, and the hub
/// stats.
fn run_faulted_fused_trace(
    engine: &Engine,
    fuse_cfg: FuseConfig,
    overlap: bool,
    prompts: &[String],
    cfg: &RunConfig,
    seed0: u64,
    max_inflight: usize,
) -> (Vec<GenOutput>, Vec<usize>, Vec<usize>, kappa::engine::FuseStats) {
    let hub = FusionHub::new(fuse_cfg);
    let sched_cfg =
        SchedConfig { max_inflight, slot_budget: 32, fuse: true, ..SchedConfig::default() };
    let mut sched: Scheduler<FusedFlight, usize> = Scheduler::new(sched_cfg);
    let admission = engine.admission_cost(cfg.concurrent_branches()).expect("admission cost");
    let mut queue: VecDeque<usize> = (0..prompts.len()).collect();
    let mut out: Vec<Option<GenOutput>> = (0..prompts.len()).map(|_| None).collect();
    let mut retries = vec![0usize; prompts.len()];
    let mut spawns = vec![0usize; prompts.len()];
    let mut ticks = 0usize;
    while !(queue.is_empty() && sched.is_empty()) {
        ticks += 1;
        assert!(ticks < 100_000, "faulted trace runaway");
        while !queue.is_empty() && sched.can_admit(admission.0, admission.1) {
            let i = queue.pop_front().unwrap();
            spawns[i] += 1;
            let driver =
                make_driver_fused(engine, &hub, &prompts[i], cfg, request_seed(seed0, i as u64))
                    .expect("fused driver");
            sched.admit(FusedFlight { driver, engine }, i);
        }
        let mut requeue: Vec<usize> = Vec::new();
        let on_done = |i: usize, r: Result<GenOutput>| match r {
            Ok(o) => out[i] = Some(o),
            Err(e) => {
                let contained = e.chain().any(|c| {
                    c.downcast_ref::<PodFault>().is_some()
                        || c.downcast_ref::<FaultError>().is_some()
                });
                assert!(contained, "request {i} failed with a non-contained error: {e:#}");
                requeue.push(i);
            }
        };
        if overlap {
            sched.tick_overlapped(|| hub.issue(engine), || hub.await_ready(), on_done);
        } else {
            sched.tick(|| hub.flush(engine), on_done);
        }
        for i in requeue {
            retries[i] += 1;
            queue.push_back(i);
        }
    }
    let stats = hub.stats();
    (
        out.into_iter().map(|o| o.expect("request never completed")).collect(),
        retries,
        spawns,
        stats,
    )
}

/// The PR 6 load-bearing claim, pinned for all four methods: under a
/// seeded transient fault plan that takes down one pod, only the
/// requests leasing rows in that pod retry — and they complete
/// **bit-identical** to a fault-free run — while every other request
/// observes zero errors and zero extra dispatches. `pod_bucket: 1`
/// clamps each pod to one request's bucket, so pod containment is
/// observable per request, and the Runtime's dispatch counter must show
/// the exact deficit of the aborted dispatches (an injected fault fires
/// *before* the execute and before the counter).
#[test]
fn injected_pod_faults_recover_bit_identical_with_containment() {
    let Some(engine) = load() else { return };
    if !packed_ready(&engine) {
        eprintln!("SKIP: artifact set has no packed executables (re-run `make artifacts`)");
        return;
    }
    let problems = Dataset::GsmSynth.generate(5, 77);
    let prompts: Vec<String> = problems.iter().map(|p| p.prompt()).collect();
    let per_request_pods = FuseConfig { pod_bucket: 1, ..FuseConfig::default() };
    let rt = engine.model().runtime();

    for method in [Method::Greedy, Method::Bon, Method::StBon, Method::Kappa] {
        let cfg = RunConfig { method, n: 4, max_new_tokens: 48, ..RunConfig::default() };
        rt.set_fault_plan(None);
        let blocking: Vec<GenOutput> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| run_method(&engine, p, &cfg, request_seed(5, i as u64)).expect("blocking"))
            .collect();

        // A transient fault at the third decode-family dispatch of each
        // flavor (whichever this method's policy uses) — each hit takes
        // down exactly one pod. The same plan runs once synchronously
        // (`--no-overlap`'s tick) and once overlapped; the fault sites
        // are decode/superstep, which fire at **issue** time in both
        // modes, so the two runs' counter ledgers must be identical
        // entry for entry (PR 9's issue-time-counting audit).
        let mut ledgers: Vec<(bool, usize, usize, Vec<usize>, Vec<usize>, usize, usize)> =
            Vec::new();
        for overlap in [false, true] {
            rt.set_fault_plan(Some(FaultPlan::parse("decode@2,superstep@2").expect("plan")));
            let before = rt.decode_dispatch_count();
            let (fused, retries, spawns, stats) =
                run_faulted_fused_trace(&engine, per_request_pods, overlap, &prompts, &cfg, 5, 3);
            let plan = rt.fault_plan().expect("plan installed");
            let injected =
                plan.injected_at(FaultSite::Decode) + plan.injected_at(FaultSite::Superstep);
            let dispatched = rt.decode_dispatch_count() - before;
            rt.set_fault_plan(None);

            assert!(injected >= 1, "{method:?} (overlap {overlap}): the fault plan never fired");
            assert_eq!(
                stats.pod_faults, injected,
                "{method:?} (overlap {overlap}): every injected fault must be contained pod-side"
            );
            // Recovery is bit-identical for everyone, victims included.
            for (i, (b, f)) in blocking.iter().zip(&fused).enumerate() {
                assert_outputs_identical(
                    b,
                    f,
                    &format!("{method:?} request {i} under injected faults (overlap {overlap})"),
                );
            }
            // Containment: one retry per injected fault, landing only on
            // the faulted pod's request; bystanders spawn exactly once
            // (zero extra dispatches).
            assert_eq!(
                retries.iter().sum::<usize>(),
                injected,
                "{method:?} (overlap {overlap}): retries {retries:?} must match injected faults"
            );
            for (i, (&r, &s)) in retries.iter().zip(&spawns).enumerate() {
                assert_eq!(
                    s,
                    1 + r,
                    "{method:?} request {i} (overlap {overlap}): spawns must be 1 + retries"
                );
            }
            // The dispatch/pod-tick ledger: an aborted dispatch was
            // counted as an occupied pod-tick but never reached the
            // execute, so the fused invariant becomes an exact deficit.
            assert_eq!(
                dispatched,
                stats.occupied_pod_ticks - injected,
                "{method:?} (overlap {overlap}): decode dispatches must equal \
                 occupied pod-ticks minus injected faults"
            );
            ledgers.push((
                overlap,
                injected,
                dispatched,
                retries,
                spawns,
                stats.pod_faults,
                stats.occupied_pod_ticks,
            ));
        }
        // The cross-mode audit: identical fault plan, identical counter
        // ledger. `note_*` moves at issue time only, so moving the
        // awaits must not move a single counter.
        let (sync, over) = (&ledgers[0], &ledgers[1]);
        assert_eq!(
            (&sync.1, &sync.2, &sync.3, &sync.4, &sync.5, &sync.6),
            (&over.1, &over.2, &over.3, &over.4, &over.5, &over.6),
            "{method:?}: the overlapped run's counter ledger diverged from --no-overlap"
        );
    }
}

/// Eviction × overlap (PR 9): a fused request evicted mid-flight under
/// the software-pipelined tick — its driver (and pod lease) dropped
/// between ticks, where the end-of-tick drain guarantees no ticket is
/// outstanding — re-admits, re-prefills, and completes bit-identical
/// to its blocking run, for all four methods. The synchronous tick runs
/// the same eviction trace as the oracle.
#[test]
fn evicted_fused_requests_under_overlap_are_bit_identical() {
    let Some(engine) = load() else { return };
    if !packed_ready(&engine) {
        eprintln!("SKIP: artifact set has no packed executables (re-run `make artifacts`)");
        return;
    }
    let problems = Dataset::GsmSynth.generate(4, 19);
    let prompts: Vec<String> = problems.iter().map(|p| p.prompt()).collect();
    let order: Vec<usize> = (0..prompts.len()).collect();

    for method in [Method::Greedy, Method::Bon, Method::StBon, Method::Kappa] {
        let cfg = RunConfig { method, n: 4, max_new_tokens: 48, ..RunConfig::default() };
        let blocking: Vec<GenOutput> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| run_method(&engine, p, &cfg, request_seed(5, i as u64)).expect("blocking"))
            .collect();
        for overlap in [false, true] {
            let (fused, _stats) = run_fused_trace_with(
                &engine,
                FuseConfig::default(),
                false,
                overlap,
                Some(4),
                &prompts,
                &cfg,
                5,
                &order,
                1,
                3,
            );
            for (i, (b, f)) in blocking.iter().zip(&fused).enumerate() {
                assert_outputs_identical(
                    b,
                    f,
                    &format!(
                        "{method:?} request {i} after a fused evict/re-admit (overlap {overlap})"
                    ),
                );
            }
        }
    }
}

/// Evict/re-admit round trip: drivers are deterministic in
/// `(prompt, seed)`, so dropping a partially-run driver (an eviction —
/// its device residence is released on drop) and restarting it from
/// scratch must reproduce the blocking run bit-for-bit. This is the
/// property that makes `PreemptPolicy::EvictYoungest` a latency trade,
/// never a correctness one.
#[test]
fn evicted_and_readmitted_requests_are_bit_identical_to_blocking_runs() {
    let Some(engine) = load() else { return };
    let problems = Dataset::GsmSynth.generate(2, 57);

    for method in [Method::Greedy, Method::Bon, Method::StBon, Method::Kappa] {
        let cfg = RunConfig { method, n: 4, max_new_tokens: 48, ..RunConfig::default() };
        for (i, p) in problems.iter().enumerate() {
            let prompt = p.prompt();
            let seed = request_seed(3, i as u64);
            let blocking = run_method(&engine, &prompt, &cfg, seed).expect("blocking");

            // First tenancy: part of the request runs, then the driver
            // is dropped mid-flight (the eviction).
            let mut evicted = make_driver(&engine, &prompt, &cfg, seed).expect("driver");
            for _ in 0..5 {
                if let StepOutcome::Done(_) = evicted.poll_step(&engine).expect("poll") {
                    break;
                }
            }
            drop(evicted);

            // Re-admission: a fresh driver re-prefills from scratch.
            let mut readmitted = make_driver(&engine, &prompt, &cfg, seed).expect("driver");
            let out = loop {
                if let StepOutcome::Done(out) = readmitted.poll_step(&engine).expect("poll") {
                    break out;
                }
            };
            assert_outputs_identical(
                &blocking,
                &out,
                &format!("{method:?} request {i} after an evict/re-admit round trip"),
            );
        }
    }
}
