//! Fused-superstep parity suite: the decode+signals superstep must be
//! **bit-identical** to the unfused `decode` → `signals_padded` sequence
//! it replaced — same logits, same (KL, confidence, entropy) — across
//! buckets, padding rows, and NaN-poisoned inputs. The unfused pair
//! stays alive precisely so this differential oracle keeps running.
//!
//! Artifact-gated tests skip (loudly) when `artifacts/` is absent; the
//! pure-logic tests at the bottom (signal-row repack permutation) always
//! run.

use std::sync::Arc;

use kappa::engine::{repack_rows, Engine};
use kappa::runtime::{KvCache, LoadedModel, Manifest, Runtime};

fn artifacts_dir() -> String {
    std::env::var("KAPPA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn load() -> Option<Arc<Engine>> {
    let manifest = match Manifest::load(artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP (no artifacts — run `make artifacts`): {e:#}");
            return None;
        }
    };
    let rt = Arc::new(Runtime::new().expect("pjrt client"));
    let model = LoadedModel::load(rt, &manifest, "sm").expect("load sm");
    Some(Arc::new(Engine::new(Arc::new(model))))
}

/// Prefill a short prompt and broadcast the primed cache to `bucket`.
fn primed_cache(engine: &Engine, bucket: usize) -> (Vec<i32>, usize, KvCache) {
    let model = engine.model();
    let tok = engine.tokenizer();
    let (ids, len) = tok.encode_prompt("q: 12+34?\na:", model.config.prompt_len).unwrap();
    let ids_i32: Vec<i32> = ids[..len].iter().map(|&t| t as i32).collect();
    let (_, cache1) = model.prefill(&ids_i32).unwrap();
    let idx = vec![0i32; bucket];
    let cache = model.gather(&cache1, bucket, &idx).unwrap();
    (ids_i32, len, cache)
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
    }
}

#[test]
fn superstep_is_bit_identical_to_decode_then_signals_across_buckets() {
    let Some(engine) = load() else { return };
    let model = engine.model();
    for &b in model.buckets() {
        if !model.has_superstep(b) {
            eprintln!("SKIP bucket {b}: artifact set has no superstep");
            continue;
        }
        let (_, len, cache) = primed_cache(&engine, b);
        let tokens: Vec<i32> = (0..b as i32).map(|i| 5 + (i % 7)).collect();

        // Unfused oracle: decode (non-destructive), then score the
        // downloaded slab with the standalone signal executable.
        let (logits_u, cache_u) = model.decode(&tokens, len, &cache).unwrap();
        let (kl_u, conf_u, ent_u) = model.signals_padded(&logits_u, b, b).unwrap();

        // Fused superstep on an identical predecessor cache.
        let (_, _, mut cache_f) = primed_cache(&engine, b);
        let (mut lg, mut kl, mut conf, mut ent) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        model
            .superstep_into(&tokens, len, &mut cache_f, &mut lg, &mut kl, &mut conf, &mut ent)
            .unwrap();

        assert_bits_eq(&lg, &logits_u, "logits");
        assert_bits_eq(&kl, &kl_u, "kl");
        assert_bits_eq(&conf, &conf_u, "conf");
        assert_bits_eq(&ent, &ent_u, "ent");

        // Successor caches must step identically (k/v parity): one more
        // decode from each must give the same logits.
        let tokens2: Vec<i32> = vec![3; b];
        let (next_u, _) = model.decode(&tokens2, len + 1, &cache_u).unwrap();
        let (next_f, _) = model.decode(&tokens2, len + 1, &cache_f).unwrap();
        assert_bits_eq(&next_f, &next_u, "successor-cache logits");
    }
}

#[test]
fn superstep_padding_rows_do_not_disturb_live_rows() {
    let Some(engine) = load() else { return };
    let model = engine.model();
    let b = 4;
    if !model.has_superstep(b) {
        eprintln!("SKIP: no superstep for bucket {b}");
        return;
    }
    let v = model.config.vocab;
    let rows = 2; // live rows; 2 padding rows carry stale tokens

    let (_, len, mut cache_a) = primed_cache(&engine, b);
    let (_, _, mut cache_b) = primed_cache(&engine, b);
    let tok_a = vec![5, 9, 0, 0];
    let tok_b = vec![5, 9, 7, 11]; // different garbage in padding rows

    let mk = || (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let (mut lg_a, mut kl_a, mut cf_a, mut en_a) = mk();
    let (mut lg_b, mut kl_b, mut cf_b, mut en_b) = mk();
    model
        .superstep_into(&tok_a, len, &mut cache_a, &mut lg_a, &mut kl_a, &mut cf_a, &mut en_a)
        .unwrap();
    model
        .superstep_into(&tok_b, len, &mut cache_b, &mut lg_b, &mut kl_b, &mut cf_b, &mut en_b)
        .unwrap();

    // Live rows are independent of padding-row contents.
    assert_bits_eq(&lg_a[..rows * v], &lg_b[..rows * v], "live logits rows");
    assert_bits_eq(&kl_a[..rows], &kl_b[..rows], "live kl rows");
    assert_bits_eq(&cf_a[..rows], &cf_b[..rows], "live conf rows");
    assert_bits_eq(&en_a[..rows], &en_b[..rows], "live ent rows");
}

#[test]
fn nan_logits_degrade_deterministically_not_fatally() {
    let Some(engine) = load() else { return };
    let model = engine.model();
    let b = 2;
    let v = model.config.vocab;
    // Row 0 poisoned with NaN, row 1 clean.
    let mut slab: Vec<f32> = (0..b * v).map(|i| ((i * 131) % 97) as f32 / 9.0 - 5.0).collect();
    slab[3] = f32::NAN;

    let (kl, conf, ent) = model.signals_padded(&slab, b, b).expect("NaN must not fail the call");
    // Poisoned row: NaN propagates through softmax → all three signals.
    assert!(kl[0].is_nan() && conf[0].is_nan() && ent[0].is_nan(), "{kl:?} {conf:?} {ent:?}");
    // Clean row is bit-identical to scoring it without the poisoned
    // neighbour (row-wise reductions never mix rows).
    let mut clean = slab.clone();
    for x in &mut clean[..v] {
        *x = 0.0;
    }
    let (kl2, conf2, ent2) = model.signals_padded(&clean, b, b).unwrap();
    assert_eq!(kl[1].to_bits(), kl2[1].to_bits());
    assert_eq!(conf[1].to_bits(), conf2[1].to_bits());
    assert_eq!(ent[1].to_bits(), ent2[1].to_bits());
    // And determinism: the same poisoned slab scores identically twice.
    let (kl3, _, _) = model.signals_padded(&slab, b, b).unwrap();
    assert_eq!(kl[0].to_bits(), kl3[0].to_bits());
}

#[test]
fn engine_fused_signals_survive_pruning_repack() {
    let Some(engine) = load() else { return };
    let model = engine.model();
    if !model.has_superstep(4) || !model.has_superstep(2) {
        eprintln!("SKIP: artifact set has no superstep");
        return;
    }
    let mut state = engine.start("q: 12+34?\na:", 4).unwrap();
    // One fused step over all four branches.
    let sampled: Vec<(u32, f64)> = (0..4).map(|i| (5 + i as u32, -1.0)).collect();
    state.step_fused(&engine, &sampled).unwrap();
    let (kl_all, conf_all, ent_all) = {
        let (a, b, c) = state.fused_signals().expect("fused rows cached");
        (a.to_vec(), b.to_vec(), c.to_vec())
    };

    // Prune to branches {2, 0}: the cached signal rows must follow the
    // same permutation the logits slab does.
    state.retain_branches(&engine, &[2, 0]).unwrap();
    let (kl, conf, ent) = state.fused_signals().expect("still valid after repack");
    assert_eq!(kl.len(), 2);
    for (dst, src) in [(0usize, 2usize), (1, 0)] {
        assert_eq!(kl[dst].to_bits(), kl_all[src].to_bits(), "kl row {dst}");
        assert_eq!(conf[dst].to_bits(), conf_all[src].to_bits(), "conf row {dst}");
        assert_eq!(ent[dst].to_bits(), ent_all[src].to_bits(), "ent row {dst}");
    }
    // The repacked rows must equal re-scoring the repacked slab from
    // scratch with the standalone executable (the unfused oracle).
    let (kl_o, conf_o, ent_o) =
        model.signals_padded(state.logits_slab(), state.n_live(), state.bucket()).unwrap();
    assert_bits_eq(kl, &kl_o, "kl vs oracle");
    assert_bits_eq(conf, &conf_o, "conf vs oracle");
    assert_bits_eq(ent, &ent_o, "ent vs oracle");

    // A plain (non-gated) step invalidates the cache.
    state.step(&engine, &[(5, -1.0), (6, -1.0)]).unwrap();
    assert!(state.fused_signals().is_none());
}

// ---- pure-logic tests (no artifacts needed) ----

#[test]
fn repack_rows_applies_arbitrary_permutations() {
    // 3 rows of width 2, keep slots [2, 0] into a 4-row destination.
    let mut src = vec![0.0, 0.1, 1.0, 1.1, 2.0, 2.1];
    let mut spare = Vec::new();
    repack_rows(&mut src, &mut spare, &[2, 0], 2, 4);
    assert_eq!(src, vec![2.0, 2.1, 0.0, 0.1, 0.0, 0.0, 0.0, 0.0]);

    // Descending keep order must not clobber sources (regression for an
    // in-place shuffle): [1, 0] swaps the two rows.
    let mut src = vec![10.0, 20.0];
    repack_rows(&mut src, &mut spare, &[1, 0], 1, 2);
    assert_eq!(src, vec![20.0, 10.0]);
}

#[test]
fn repack_rows_is_allocation_free_at_high_water_mark() {
    let mut src = vec![1.0f32; 8];
    let mut spare = Vec::with_capacity(8);
    let spare_base = spare.as_ptr();
    repack_rows(&mut src, &mut spare, &[1, 0], 4, 2);
    // After the swap, `spare` holds the old src allocation and vice
    // versa; repeating the repack ping-pongs between the same two
    // buffers without reallocating.
    let src_base = src.as_ptr();
    assert_eq!(src_base, spare_base);
    repack_rows(&mut src, &mut spare, &[0, 1], 4, 2);
    repack_rows(&mut src, &mut spare, &[1, 0], 4, 2);
    assert_eq!(src.as_ptr(), src_base);
}

#[test]
fn repack_rows_preserves_nan_payloads_bitwise() {
    // NaN scores must survive the repack bit-for-bit — degradation
    // stays deterministic end to end.
    let weird = f32::from_bits(0x7fc0_dead);
    let mut src = vec![1.0, weird, 3.0];
    let mut spare = Vec::new();
    repack_rows(&mut src, &mut spare, &[1, 2], 1, 2);
    assert_eq!(src[0].to_bits(), 0x7fc0_dead);
    assert_eq!(src[1], 3.0);
}
