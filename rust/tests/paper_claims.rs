//! The paper's §4.2 claims as executable assertions (small problem set;
//! the full-scale version is `cargo bench --bench table1_full_grid`).
//! Skips without artifacts.

use std::sync::Arc;

use kappa::coordinator::config::{Method, RunConfig};
use kappa::coordinator::metrics_for;
use kappa::data::Dataset;
use kappa::engine::Engine;
use kappa::metrics::RunMetrics;
use kappa::runtime::{LoadedModel, Manifest, Runtime};

fn artifacts_dir() -> String {
    std::env::var("KAPPA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn engine_for(model: &str) -> Option<Engine> {
    let manifest = Manifest::load(artifacts_dir()).ok()?;
    let rt = Arc::new(Runtime::new().ok()?);
    let lm = LoadedModel::load(rt, &manifest, model).ok()?;
    Some(Engine::new(Arc::new(lm)))
}

fn run(engine: &Engine, ds: Dataset, method: Method, n: usize, problems: usize) -> RunMetrics {
    let cfg = RunConfig { method, n, max_new_tokens: 80, seed: 3, ..RunConfig::default() };
    let set = ds.generate(problems, 1717);
    metrics_for(engine, &set, &cfg).expect("run")
}

/// "KL consistently reduces total token generation compared to BoN" and
/// "KL consistently lowers peak GPU memory compared to BoN" (§4.2).
#[test]
fn kl_beats_bon_on_cost_axes() {
    let Some(engine) = engine_for("sm") else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    for ds in [Dataset::GsmSynth, Dataset::MathSynth] {
        for n in [5, 10] {
            let bon = run(&engine, ds, Method::Bon, n, 8);
            let kl = run(&engine, ds, Method::Kappa, n, 8);
            assert!(
                kl.mean_total_tokens() < bon.mean_total_tokens(),
                "{ds:?} N={n}: tokens {} !< {}",
                kl.mean_total_tokens(),
                bon.mean_total_tokens()
            );
            assert!(
                kl.peak_mem_mb() < bon.peak_mem_mb(),
                "{ds:?} N={n}: memory {} !< {}",
                kl.peak_mem_mb(),
                bon.peak_mem_mb()
            );
        }
    }
}

/// Token reduction grows with N (the paper's Fig. 3 trend: the bigger the
/// branch budget, the more KAPPA saves relative to BoN).
#[test]
fn token_reduction_grows_with_n() {
    let Some(engine) = engine_for("sm") else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let ds = Dataset::GsmSynth;
    let red = |n: usize| {
        let bon = run(&engine, ds, Method::Bon, n, 8);
        let kl = run(&engine, ds, Method::Kappa, n, 8);
        1.0 - kl.mean_total_tokens() / bon.mean_total_tokens()
    };
    let (r5, r20) = (red(5), red(20));
    assert!(
        r20 > r5,
        "reduction should grow with N: N=5 → {r5:.3}, N=20 → {r20:.3}"
    );
    assert!(r20 > 0.4, "N=20 reduction should be substantial, got {r20:.3}");
}

/// Greedy is the memory floor: every multi-branch method's peak is at or
/// above greedy's (M_cost ≥ 1), and KAPPA's M_cost stays below BoN's.
#[test]
fn memory_cost_ordering() {
    let Some(engine) = engine_for("sm") else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let ds = Dataset::MathSynth;
    let greedy = run(&engine, ds, Method::Greedy, 1, 8);
    let bon = run(&engine, ds, Method::Bon, 10, 8);
    let kl = run(&engine, ds, Method::Kappa, 10, 8);
    let g = greedy.peak_mem_mb();
    assert!(bon.peak_mem_mb() / g >= 1.0);
    assert!(kl.peak_mem_mb() / g >= 1.0);
    assert!(kl.peak_mem_mb() < bon.peak_mem_mb());
}

/// ST-BoN and KAPPA land in the same cost regime (both truncate early);
/// final-branch tokens stay in the same range as greedy's output length
/// (the "Final Branch Tokens" column is method-invariant to first order).
#[test]
fn final_branch_tokens_are_method_invariant() {
    let Some(engine) = engine_for("sm") else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let ds = Dataset::GsmSynth;
    let greedy = run(&engine, ds, Method::Greedy, 1, 8).mean_final_branch_tokens();
    for method in [Method::Bon, Method::StBon, Method::Kappa] {
        let m = run(&engine, ds, method, 5, 8).mean_final_branch_tokens();
        assert!(
            m > 0.3 * greedy && m < 3.0 * greedy,
            "{method:?}: final tokens {m:.1} far from greedy {greedy:.1}"
        );
    }
}
