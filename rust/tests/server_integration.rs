//! Server-level integration: boots the worker pool on real artifacts,
//! pushes a small trace, checks responses and telemetry. Skips when
//! artifacts are missing.

use kappa::coordinator::config::{Method, RunConfig};
use kappa::data::{eval, Dataset};
use kappa::server::Server;

fn artifacts_dir() -> String {
    std::env::var("KAPPA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/manifest.json", artifacts_dir())).exists()
}

#[test]
fn server_serves_a_trace() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = RunConfig { method: Method::Kappa, n: 4, max_new_tokens: 64, ..RunConfig::default() };
    let server = Server::start(&artifacts_dir(), "sm", 1, cfg).expect("boot server");

    let problems = Dataset::GsmSynth.generate(4, 31);
    let prompts: Vec<String> = problems.iter().map(|p| p.prompt()).collect();
    let responses = server.submit_all(&prompts, 5);

    assert_eq!(responses.len(), 4);
    for (resp, prob) in responses.iter().zip(&problems) {
        let r = resp.as_ref().expect("response ok");
        assert!(r.service_seconds > 0.0);
        assert!(r.output.metrics.total_tokens > 0);
        // Answer may be wrong (tiny model), but the text must be decodable
        // and extraction must not panic.
        let _ = eval::is_correct(&r.output.text, prob.answer);
    }
    server.shutdown();
}

#[test]
fn server_rejects_bad_model_at_startup() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = RunConfig::default();
    let err = Server::start(&artifacts_dir(), "nonexistent-model", 1, cfg);
    assert!(err.is_err(), "startup must fail loudly for unknown model");
}

#[test]
fn server_handles_oversized_prompt_gracefully() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let cfg = RunConfig { method: Method::Greedy, n: 1, ..RunConfig::default() };
    let server = Server::start(&artifacts_dir(), "sm", 1, cfg).expect("boot");
    let huge = "q: ".to_string() + &"1+".repeat(200) + "1?\na:";
    let rx = server.submit(&huge, 0).expect("queue open");
    let resp = rx.recv().expect("channel alive");
    assert!(resp.is_err(), "oversized prompt should error, not crash the worker");
    // Worker must survive and serve the next request.
    let ok = server.submit("q: 1+1?\na:", 0).expect("queue open").recv().expect("alive");
    assert!(ok.is_ok());
    server.shutdown();
}
