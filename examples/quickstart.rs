//! Quickstart: load the small model from `artifacts/`, decode one
//! math word problem with KAPPA (N = 5 branches), print the chosen
//! chain-of-thought and the extracted answer.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use kappa::coordinator::config::{Method, RunConfig};
use kappa::coordinator::run_method;
use kappa::data::eval;
use kappa::engine::Engine;
use kappa::runtime::{LoadedModel, Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (HLO text + weights + manifest).
    let manifest = Manifest::load("artifacts")?;
    let rt = Arc::new(Runtime::new()?);
    let model = Arc::new(LoadedModel::load(rt, &manifest, "sm")?);
    let engine = Engine::new(model);

    // 2. Ask a question in the dataset's format.
    let prompt = "q: mia has 3 boxes of 4 pens each. how many pens in total?\na:";
    println!("prompt: {prompt:?}");

    // 3. Decode with KAPPA (paper defaults: T=0.7/top-k 20/top-p 0.95,
    //    α=0.5, w=16, m=4, weights (0.7, 0.2, 0.1), linear schedule).
    let cfg = RunConfig { method: Method::Kappa, n: 5, ..RunConfig::default() };
    let t0 = std::time::Instant::now();
    let out = run_method(&engine, prompt, &cfg, /*seed=*/ 7)?;

    println!("chain-of-thought:{}", out.text.trim_end());
    println!("answer: {:?}", eval::extract_answer(&out.text));
    println!(
        "branch {} won; generated {} tokens total across {} branches, peak memory {:.1} MB, {:.2}s",
        out.chosen_branch,
        out.metrics.total_tokens,
        cfg.n,
        out.metrics.peak_mem_bytes as f64 / (1024.0 * 1024.0),
        t0.elapsed().as_secs_f64(),
    );
    Ok(())
}
