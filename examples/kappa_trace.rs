//! Annotated KAPPA walk-through: runs the three phases step by step on
//! one problem and prints what the algorithm sees — per-branch KL /
//! confidence / entropy signals, the robustified EMA, trajectory scores,
//! and every pruning decision. Built entirely from the public engine +
//! signal-pipeline API, so it doubles as an executable explanation of
//! Algorithm 2.
//!
//!   cargo run --release --example kappa_trace -- --n 5

use std::sync::Arc;

use kappa::coordinator::config::{KappaConfig, SamplerConfig};
use kappa::coordinator::signals::{combine_scores, BranchSignalState};
use kappa::coordinator::{draft, sampler, schedule};
use kappa::engine::Engine;
use kappa::runtime::{LoadedModel, Manifest, Runtime};
use kappa::util::cli::Args;
use kappa::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 5);
    let seed = args.u64_or("seed", 11);
    let prompt = args.str_or("prompt", "q: compute (7*6+4) mod 5.\na:");

    let manifest = Manifest::load(args.str_or("artifacts", "artifacts"))?;
    let rt = Arc::new(Runtime::new()?);
    let model = Arc::new(LoadedModel::load(rt, &manifest, &args.str_or("model", "sm"))?);
    let engine = Engine::new(model);

    let kcfg = KappaConfig::default();
    let scfg = SamplerConfig::default();
    let tau = kcfg.effective_tau(n);
    let tok = engine.tokenizer().clone();

    println!("prompt: {prompt:?}");
    println!("N={n}, τ={tau}, α={}, w={}, m={}, weights=({},{},{})\n", kcfg.ema_alpha, kcfg.window, kcfg.mom_buckets, kcfg.w_kl, kcfg.w_conf, kcfg.w_ent);

    let mut state = engine.start(&prompt, n)?;
    let mut rngs: Vec<Pcg64> = (0..n).map(|i| Pcg64::new(seed, i as u64 + 1)).collect();
    let mut steps = 0usize;

    // ---- Phase I: draft until pairwise inconsistency ----
    println!("— Phase I (draft) —");
    loop {
        let seqs: Vec<&[u32]> =
            state.live_branches().iter().map(|&bi| state.branches[bi].tokens.as_slice()).collect();
        if (steps > 0 && draft::all_pairwise_inconsistent(&seqs)) || steps >= kcfg.max_draft {
            break;
        }
        let live = state.live_branches().to_vec();
        let sampled: Vec<(u32, f64)> = live
            .iter()
            .enumerate()
            .map(|(slot, &bi)| sampler::sample(state.logits_for_slot(slot), &scfg, &mut rngs[bi]))
            .collect();
        state.step(&engine, &sampled)?;
        steps += 1;
        state.compact_finished(&engine)?;
    }
    println!("cutoff c = {steps} (all {n} branches pairwise inconsistent)");
    for &bi in state.live_branches() {
        println!("  branch {bi}: {:?}", tok.decode(&state.branches[bi].tokens));
    }

    // ---- Phase II: scoring & gating ----
    println!("\n— Phase II (scoring & gating over τ={tau} steps) —");
    let mut sig: Vec<BranchSignalState> =
        (0..n).map(|_| BranchSignalState::new(kcfg.window)).collect();
    let mut k = 0usize;
    while k < tau && state.n_live() > 0 && state.remaining() > 0 {
        k += 1;
        let live = state.live_branches().to_vec();
        let rows = live.len();
        // Zero-copy: the engine's slab is already bucket-padded.
        let (kl, conf, ent) =
            engine.model().signals_padded(state.logits_slab(), rows, state.bucket())?;
        let mut ema = Vec::with_capacity(rows);
        for (slot, &bi) in live.iter().enumerate() {
            ema.push(sig[bi].update_kl(kl[slot] as f64, &kcfg));
        }
        let confs: Vec<f64> = conf.iter().map(|&x| x as f64).collect();
        let ents: Vec<f64> = ent.iter().map(|&x| x as f64).collect();
        combine_scores(&mut sig, &live, &ema, &confs, &ents, steps + 1, &kcfg);

        let sampled: Vec<(u32, f64)> = live
            .iter()
            .enumerate()
            .map(|(slot, &bi)| sampler::sample(state.logits_for_slot(slot), &scfg, &mut rngs[bi]))
            .collect();
        state.step(&engine, &sampled)?;
        steps += 1;

        let target = schedule::survivors(kcfg.schedule, n, k, tau);
        print!("k={k:<3} target R={target:<3}");
        for (slot, &bi) in live.iter().enumerate() {
            print!(
                "  b{bi}[kl={:.2} c={:.2} h={:.2} S={:+.3}]",
                kl[slot], conf[slot], ent[slot], sig[bi].score
            );
        }
        println!();

        let candidates: Vec<usize> =
            (0..state.branches.len()).filter(|&bi| !state.branches[bi].pruned).collect();
        let target = target.min(candidates.len()).max(1);
        if target < candidates.len() {
            let mut ranked = candidates.clone();
            ranked.sort_by(|&a, &b| kappa::util::stats::total_order(sig[b].score, sig[a].score));
            let keep = &ranked[..target];
            let keep_live: Vec<usize> = state
                .live_branches()
                .iter()
                .copied()
                .filter(|bi| keep.contains(bi))
                .collect();
            for &bi in &candidates {
                if !keep.contains(&bi) {
                    println!("      ✂ prune branch {bi} (S={:+.3}) → bucket may shrink", sig[bi].score);
                }
            }
            if keep_live.is_empty() {
                break;
            }
            state.retain_branches(&engine, &keep_live)?;
            for &bi in &candidates {
                if !keep.contains(&bi) {
                    state.branches[bi].pruned = true;
                }
            }
        }
        if !state.compact_finished(&engine)? {
            break;
        }
    }

    // ---- Phase III: continuation ----
    let survivors: Vec<usize> =
        (0..state.branches.len()).filter(|&bi| !state.branches[bi].pruned).collect();
    let chosen = survivors
        .iter()
        .copied()
        .max_by(|&a, &b| kappa::util::stats::total_order(sig[a].score, sig[b].score))
        .unwrap_or(0);
    println!("\n— Phase III (continuation) — winner: branch {chosen} (S={:+.3})", sig[chosen].score);
    if !state.branches[chosen].finished && state.live_branches().contains(&chosen) {
        state.retain_branches(&engine, &[chosen])?;
        let mut rng = rngs[chosen].clone();
        while !state.all_finished() && state.remaining() > 0 && steps < 96 {
            let (t, lp) = sampler::sample(state.logits_for_slot(0), &scfg, &mut rng);
            state.step(&engine, &[(t, lp)])?;
            steps += 1;
        }
    }
    println!("output: {:?}", state.text_of(&engine, chosen));
    println!(
        "answer: {:?} | total tokens {} | peak mem {:.1} MB | {} decode calls, {} gathers",
        kappa::data::eval::extract_answer(&state.text_of(&engine, chosen)),
        state.total_tokens(),
        state.mem.peak_mb(),
        state.decode_calls,
        state.gather_calls,
    );
    Ok(())
}
