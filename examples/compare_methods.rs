//! Side-by-side method comparison on the same problems — the paper's
//! story in one terminal screen: greedy vs Full-BoN vs ST-BoN vs KAPPA on
//! a handful of problems, with per-method accuracy/token/memory totals.
//!
//!   cargo run --release --example compare_methods -- --problems 10 --n 10

use std::sync::Arc;

use kappa::coordinator::config::{Method, RunConfig};
use kappa::coordinator::metrics_for;
use kappa::data::Dataset;
use kappa::engine::Engine;
use kappa::runtime::{LoadedModel, Manifest, Runtime};
use kappa::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_problems = args.usize_or("problems", 10);
    let n = args.usize_or("n", 10);
    let model_name = args.str_or("model", "sm");
    let dataset = Dataset::parse(&args.str_or("dataset", "math")).expect("gsm|math");

    let manifest = Manifest::load(args.str_or("artifacts", "artifacts"))?;
    let rt = Arc::new(Runtime::new()?);
    let model = Arc::new(LoadedModel::load(rt, &manifest, &model_name)?);
    let engine = Engine::new(model);

    let problems = dataset.generate(n_problems, 4242);
    println!(
        "model {model_name} on {} — {n_problems} problems, N={n}\n",
        dataset.name()
    );
    println!(
        "{:>8}  {:>8}  {:>10}  {:>10}  {:>9}  {:>8}",
        "method", "accuracy", "final_tok", "total_tok", "peak_MB", "time_s"
    );
    for method in Method::all() {
        let cfg = RunConfig { method, n, ..RunConfig::default() };
        let m = metrics_for(&engine, &problems, &cfg)?;
        println!(
            "{:>8}  {:>8.3}  {:>10.1}  {:>10.1}  {:>9.1}  {:>8.2}",
            method.name(),
            m.accuracy(),
            m.mean_final_branch_tokens(),
            m.mean_total_tokens(),
            m.peak_mem_mb(),
            m.mean_wall_seconds(),
        );
    }
    Ok(())
}
