//! End-to-end serving driver (the repo's headline validation run).
//!
//! Boots the batched request server on the trained small model, replays a
//! mixed gsm/math request trace through the KAPPA policy, and reports the
//! numbers a serving team cares about: throughput (req/s, tok/s), latency
//! percentiles (queue + service), accuracy, token cost and peak memory —
//! then repeats the trace with Full-BoN to show the serving-level effect
//! of inference-time pruning. Results are recorded in EXPERIMENTS.md.
//!
//!   make artifacts && cargo run --release --example serve_benchmark
//!   (flags: --requests 40 --model sm --n 5 --workers 1)

use kappa::coordinator::config::{Method, RunConfig};
use kappa::data::{eval, Dataset};
use kappa::server::Server;
use kappa::util::cli::Args;
use kappa::util::stats;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize_or("requests", 40);
    let model = args.str_or("model", "sm");
    let workers = args.usize_or("workers", 1);
    let n = args.usize_or("n", 5);
    let dir = args.str_or("artifacts", "artifacts");

    // Mixed trace: alternate gsm / math problems, like a real queue.
    let gsm = Dataset::GsmSynth.generate(n_requests / 2 + 1, 1001);
    let math = Dataset::MathSynth.generate(n_requests / 2 + 1, 2002);
    let mut problems = Vec::new();
    for i in 0..n_requests {
        problems.push(if i % 2 == 0 { gsm[i / 2].clone() } else { math[i / 2].clone() });
    }
    let prompts: Vec<String> = problems.iter().map(|p| p.prompt()).collect();

    for method in [Method::Kappa, Method::Bon] {
        let cfg = RunConfig { method, n, ..RunConfig::default() };
        eprintln!("\n=== {} (N={n}, {workers} worker(s), {n_requests} requests) ===", method.name());
        let server = Server::start(&dir, &model, workers, cfg)?;
        let t0 = std::time::Instant::now();
        let responses = server.submit_all(&prompts, 42);
        let wall = t0.elapsed().as_secs_f64();

        let mut lat = Vec::new();
        let mut correct = 0usize;
        let mut tokens = 0usize;
        let mut peak_mb: f64 = 0.0;
        let mut serve_kv_mb: f64 = 0.0;
        let mut serve_stats = kappa::metrics::ServeMetrics::default();
        for (resp, prob) in responses.iter().zip(&problems) {
            let r = resp.as_ref().expect("request failed");
            lat.push(r.queue_seconds + r.service_seconds);
            serve_stats.push(r.queue_seconds, r.service_seconds, r.inflight);
            tokens += r.output.metrics.total_tokens;
            // Per-request peak (the paper's M_peak column) and the
            // worker's co-resident KV high-water mark are different
            // numbers once requests overlap — report both.
            peak_mb = peak_mb.max(r.output.metrics.peak_mem_bytes as f64 / (1024.0 * 1024.0));
            serve_kv_mb = serve_kv_mb.max(r.worker_kv_peak_bytes as f64 / (1024.0 * 1024.0));
            if eval::is_correct(&r.output.text, prob.answer) {
                correct += 1;
            }
        }
        println!(
            "{:6}: {:.2} req/s  {:.0} tok/s  acc {:.3}  latency p50 {:.2}s p95 {:.2}s  peak/req {:.1} MB  serve-kv {:.1} MB  total {:.1}s  inflight {:.2}",
            method.name(),
            n_requests as f64 / wall,
            tokens as f64 / wall,
            correct as f64 / n_requests as f64,
            stats::percentile(&lat, 50.0),
            stats::percentile(&lat, 95.0),
            peak_mb,
            serve_kv_mb,
            wall,
            serve_stats.mean_inflight(),
        );
        server.shutdown();
    }
    Ok(())
}
