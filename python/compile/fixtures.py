"""Cross-language numeric fixtures.

Replays a short greedy generation for each exported model **in JAX** and
records the token trace plus the prefill logits row. The Rust integration
suite (rust/tests/runtime_integration.rs) replays the same prompt through
the PJRT path and asserts agreement — locking the whole
artifact/weights/runtime chain across the language boundary.

Run after `compile.aot` (uses the cached params npz):

    python -m compile.fixtures --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from . import tokenizer
from .aot import load_params_npz
from .model import CONFIGS, decode_step, prefill

FIXTURE_PROMPTS = {
    "gsm": "q: mia has 3 boxes of 4 pens each. how many pens in total?\na:",
    "math": "q: compute (4*5+3) mod 7.\na:",
}


def greedy_trace(cfg, params, prompt: str, max_new: int = 48):
    ids, length = tokenizer.encode_prompt(prompt, cfg.prompt_len)
    pre = jax.jit(lambda p, t, l: prefill(cfg, p, t, l))
    dec = jax.jit(lambda p, tok, pos, kc, vc: decode_step(cfg, p, tok, pos, kc, vc, use_pallas=True))
    logits, kc, vc = pre(params, jnp.asarray([ids], jnp.int32), jnp.int32(length))
    first_logits = [float(x) for x in logits[0]]
    out = []
    pos = length
    tok = int(jnp.argmax(logits[0]))
    for _ in range(max_new):
        if tok == tokenizer.EOS_ID or pos >= cfg.max_seq:
            break
        out.append(tok)
        logits, kc, vc = dec(params, jnp.asarray([tok], jnp.int32), jnp.int32(pos), kc, vc)
        pos += 1
        tok = int(jnp.argmax(logits[0]))
    return {
        "prompt": prompt,
        "prompt_len": length,
        "tokens": out,
        "text": tokenizer.decode(out),
        "first_logits": first_logits,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()

    fixtures = {}
    for name, cfg in CONFIGS.items():
        npz = os.path.join(args.out, f"params_{name}.npz")
        if not os.path.exists(npz):
            print(f"[fixtures] skipping {name}: no cached params at {npz}")
            continue
        params = load_params_npz(npz)
        fixtures[name] = {
            key: greedy_trace(cfg, params, prompt, args.max_new)
            for key, prompt in FIXTURE_PROMPTS.items()
        }
        print(f"[fixtures] {name}: " + ", ".join(
            f"{k}={fixtures[name][k]['text']!r}" for k in fixtures[name]
        ))

    path = os.path.join(args.out, "fixtures.json")
    with open(path, "w") as f:
        json.dump(fixtures, f, indent=1)
    print(f"[fixtures] wrote {path}")


if __name__ == "__main__":
    main()
