"""Synthetic dataset generators (training-corpus side).

Two datasets mirror the paper's benchmarks in *shape*:

- ``gsm_synth``  — GSM8K stand-in: 2–3 step arithmetic word problems with a
  short natural-language surface form and an exact integer answer.
- ``math_synth`` — MATH500 stand-in: harder 3–4 step expression / modular
  arithmetic problems (larger operands, negative results, ``mod``).

Every sample is ``(question, chain_of_thought, answer:int)``. The serialized
training string is::

    <bos>q: {question}\na:{cot} #### {answer}\n<eos>

The Rust evaluator (``rust/src/data/``) re-implements exactly the same
templates so that serving-time problems are in-distribution for the
build-time-trained models. **Template strings are a contract** — change them
in both places or accuracy collapses.

Randomness uses an explicit linear-congruential generator (same constants as
``rust/src/util/rng.rs``'s split-mix fallback) so corpora are reproducible
across machines and languages.
"""

from __future__ import annotations

from dataclasses import dataclass


class Lcg:
    """64-bit splitmix-style deterministic generator (matches rust util::rng::SplitMix64)."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        """Uniform integer in [0, n)."""
        return self.next_u64() % n

    def range(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi] inclusive."""
        return lo + self.below(hi - lo + 1)


@dataclass
class Sample:
    question: str
    cot: str
    answer: int

    @property
    def response(self) -> str:
        return f"{self.cot} #### {self.answer}"

    def prompt(self) -> str:
        return f"q: {self.question}\na:"

    def full_text(self) -> str:
        return f"q: {self.question}\na:{self.response}\n"


NAMES = ["tom", "amy", "sam", "mia", "leo", "zoe", "max", "eva"]
ITEMS = ["apples", "coins", "books", "pens", "cards", "shells"]


def gen_gsm(rng: Lcg) -> Sample:
    """One GSM-synth sample. Mirrors rust/src/data/gsm.rs exactly."""
    t = rng.below(5)
    name = NAMES[rng.below(len(NAMES))]
    item = ITEMS[rng.below(len(ITEMS))]
    if t == 0:
        a, b = rng.range(10, 89), rng.range(10, 89)
        c = rng.range(2, min(a + b - 1, 60))
        x, y = a + b, a + b - c
        q = f"{name} has {a} {item}, buys {b} more, gives {c} away. how many {item} now?"
        cot = f" {a}+{b}={x}. {x}-{c}={y}."
        return Sample(q, cot, y)
    if t == 1:
        a, b = rng.range(10, 89), rng.range(10, 89)
        y = a + b
        q = f"{name} has {a} {item} and finds {b} more. how many {item} in total?"
        cot = f" {a}+{b}={y}."
        return Sample(q, cot, y)
    if t == 2:
        a, b = rng.range(2, 9), rng.range(3, 12)
        y = a * b
        q = f"{name} has {a} boxes of {b} {item} each. how many {item} in total?"
        cot = f" {a}*{b}={y}."
        return Sample(q, cot, y)
    if t == 3:
        a = rng.range(30, 99)
        c = rng.range(5, a - 5)
        b = rng.range(5, 60)
        x, y = a - c, a - c + b
        q = f"{name} has {a} {item}, loses {c}, then finds {b}. how many {item} now?"
        cot = f" {a}-{c}={x}. {x}+{b}={y}."
        return Sample(q, cot, y)
    a = rng.range(10, 60)
    b, k = rng.range(2, 9), rng.range(2, 9)
    x, y = b * k, a + b * k
    q = f"{name} had {a} {item}, then bought {b} packs of {k}. how many {item} now?"
    cot = f" {b}*{k}={x}. {a}+{x}={y}."
    return Sample(q, cot, y)


def gen_math(rng: Lcg) -> Sample:
    """One MATH-synth sample. Mirrors rust/src/data/math.rs exactly."""
    t = rng.below(5)
    if t == 0:
        a, b = rng.range(3, 19), rng.range(3, 19)
        c, d = rng.range(2, 49), rng.range(3, 19)
        x = a * b
        y = x + c
        z = y % d
        q = f"compute ({a}*{b}+{c}) mod {d}."
        cot = f" {a}*{b}={x}. {x}+{c}={y}. {y} mod {d}={z}."
        return Sample(q, cot, z)
    if t == 1:
        a, b = rng.range(5, 49), rng.range(5, 49)
        c, d = rng.range(5, 29), rng.range(5, 29)
        x, y = a + b, c - d
        z = x * y
        q = f"compute ({a}+{b})*({c}-{d})."
        cot = f" {a}+{b}={x}. {c}-{d}={y}. {x}*{y}={z}."
        return Sample(q, cot, z)
    if t == 2:
        a, b = rng.range(3, 19), rng.range(3, 19)
        c, d = rng.range(3, 19), rng.range(3, 19)
        x, y = a * b, c * d
        z = x - y
        q = f"compute {a}*{b}-{c}*{d}."
        cot = f" {a}*{b}={x}. {c}*{d}={y}. {x}-{y}={z}."
        return Sample(q, cot, z)
    if t == 3:
        a = rng.range(4, 25)
        b = rng.range(3, 99)
        x = a * a
        z = x + b
        q = f"let x={a}. compute x*x+{b}."
        cot = f" {a}*{a}={x}. {x}+{b}={z}."
        return Sample(q, cot, z)
    a, b, c = rng.range(10, 89), rng.range(10, 89), rng.range(10, 89)
    d = rng.range(3, 19)
    x = a + b
    y = x + c
    z = y % d
    q = f"compute ({a}+{b}+{c}) mod {d}."
    cot = f" {a}+{b}={x}. {x}+{c}={y}. {y} mod {d}={z}."
    return Sample(q, cot, z)


GENERATORS = {"gsm_synth": gen_gsm, "math_synth": gen_math}


def generate(dataset: str, n: int, seed: int) -> list[Sample]:
    rng = Lcg(seed)
    gen = GENERATORS[dataset]
    return [gen(rng) for _ in range(n)]


def mixed_corpus(n: int, seed: int) -> list[Sample]:
    """50/50 gsm/math mix used for training both model sizes."""
    rng = Lcg(seed)
    out = []
    for i in range(n):
        out.append(gen_gsm(rng) if i % 2 == 0 else gen_math(rng))
    return out
