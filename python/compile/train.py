"""Build-time trainer for the two tiny reasoner models.

Runs ONCE inside ``make artifacts`` (never on the request path): trains
``sm`` and ``lg`` on a mixed gsm/math synthetic corpus with a hand-rolled
AdamW (the image has no optax) and hands the trained parameters to
``aot.py`` for export.

Loss is next-token cross-entropy masked to the *response* region
(CoT + answer + EOS) — the model learns to reason, not to memorize prompts.
"""

from __future__ import annotations

import functools
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen, tokenizer
from .model import CONFIGS, ModelConfig, forward_train, init_params


@functools.partial(jax.jit, static_argnames=("cfg",))
def _loss_fn(params, cfg: ModelConfig, tokens, mask):
    """Masked next-token CE. tokens [B,T] int32; mask [B,T] f32 on targets."""
    logits = forward_train(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "step": jnp.zeros((), jnp.int32)}


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1))
def train_step(params, opt, cfg: ModelConfig, tokens, mask, lr, *, b1=0.9, b2=0.98, eps=1e-9, wd=0.01):
    loss, grads = jax.value_and_grad(_loss_fn)(params, cfg, tokens, mask)
    step = opt["step"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    t = step.astype(jnp.float32)
    bc1, bc2 = 1 - b1**t, 1 - b2**t

    def upd(p, m_, v_):
        return p - lr * ((m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + wd * p)

    params = jax.tree_util.tree_map(upd, params, m, v)
    return params, {"m": m, "v": v, "step": step}, loss


def build_corpus(n: int, seed: int, seq_len: int):
    """Tokenized corpus: tokens [n, seq_len] int32, mask [n, seq_len] f32.

    Each row: <bos> prompt response \n <eos> <pad>*. Mask is 1 on the
    response region (incl. the terminating EOS), 0 on prompt and padding.
    """
    samples = datagen.mixed_corpus(n, seed)
    toks = np.zeros((n, seq_len), np.int32)
    mask = np.zeros((n, seq_len), np.float32)
    kept = 0
    for s in samples:
        prompt_ids = [tokenizer.BOS_ID] + tokenizer.encode(s.prompt())
        resp_ids = tokenizer.encode(s.response + "\n") + [tokenizer.EOS_ID]
        ids = prompt_ids + resp_ids
        if len(ids) > seq_len:
            continue
        toks[kept, : len(ids)] = ids
        mask[kept, len(prompt_ids) : len(ids)] = 1.0
        kept += 1
    return toks[:kept], mask[:kept]


def cosine_lr(step, total, peak, warmup=100):
    if step < warmup:
        return peak * step / max(warmup, 1)
    frac = (step - warmup) / max(total - warmup, 1)
    return peak * 0.5 * (1 + math.cos(math.pi * frac))


TRAIN_DEFAULTS = {
    # (steps, batch, peak_lr, corpus_size) — sized for the single-core CPU
    # testbed; ~20 min per model at these settings.
    "sm": (1400, 80, 3e-3, 40000),
    "lg": (1400, 64, 2e-3, 40000),
}


def train_model(cfg: ModelConfig, *, steps=None, batch=None, peak_lr=None, corpus_n=None, seed=0, seq_len=112, log_every=100, init_from=None):
    """Train one model size; returns (params, metrics dict).

    ``init_from``: optional parameter dict to continue training from (used
    by ``aot.py --continue-from`` for incremental build-time training).
    """
    d_steps, d_batch, d_lr, d_corpus = TRAIN_DEFAULTS[cfg.name]
    steps = steps or d_steps
    batch = batch or d_batch
    peak_lr = peak_lr or d_lr
    corpus_n = corpus_n or d_corpus

    toks, mask = build_corpus(corpus_n, seed=1234 + seed, seq_len=seq_len)
    n = toks.shape[0]
    print(f"[train {cfg.name}] corpus={n} rows, seq_len={seq_len}, params={cfg.n_params():,}"
          + (" (continuing)" if init_from is not None else ""))

    params = init_from if init_from is not None else init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    rng = np.random.default_rng(seed + 7)
    t0 = time.time()
    last_loss = float("nan")
    losses = []
    for step in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        lr = cosine_lr(step, steps, peak_lr)
        params, opt, loss = train_step(params, opt, cfg, jnp.asarray(toks[idx]), jnp.asarray(mask[idx]), jnp.float32(lr))
        if step % log_every == 0 or step == 1:
            last_loss = float(loss)
            losses.append((step, last_loss))
            print(f"[train {cfg.name}] step {step}/{steps} loss={last_loss:.4f} lr={lr:.2e} ({time.time()-t0:.0f}s)")
    metrics = {
        "steps": steps,
        "batch": batch,
        "peak_lr": peak_lr,
        "corpus_rows": int(n),
        "final_loss": last_loss,
        "loss_curve": losses,
        "wall_seconds": round(time.time() - t0, 1),
    }
    return params, metrics


def greedy_eval(cfg: ModelConfig, params, dataset: str, n: int = 50, seed: int = 99, max_new: int = 80):
    """Quick greedy-decoding accuracy check (teacher-free), used as a
    training-quality gate before export."""
    from .model import decode_step, prefill  # local import to keep top light

    samples = datagen.generate(dataset, n, seed)
    correct = 0
    pre = jax.jit(lambda p, t, l: prefill(cfg, p, t, l))
    dec = jax.jit(lambda p, tok, pos, kc, vc: decode_step(cfg, p, tok, pos, kc, vc, use_pallas=False))
    for s in samples:
        ids, length = tokenizer.encode_prompt(s.prompt(), cfg.prompt_len)
        logits, kc, vc = pre(params, jnp.asarray([ids], jnp.int32), jnp.int32(length))
        out = []
        pos = length
        tok = int(jnp.argmax(logits[0]))
        for _ in range(max_new):
            if tok == tokenizer.EOS_ID or pos >= cfg.max_seq:
                break
            out.append(tok)
            logits, kc, vc = dec(params, jnp.asarray([tok], jnp.int32), jnp.int32(pos), kc, vc)
            pos += 1
            tok = int(jnp.argmax(logits[0]))
        text = tokenizer.decode(out)
        if f"#### {s.answer}" in text:
            correct += 1
    return correct / n


def collect_tap_rollouts(cfg: ModelConfig, params, dataset: str, n: int, seed: int = 31, max_new: int = 80):
    """Greedy tapped rollouts for probe fitting: one row per decode step.

    Each decode step's post-final-layernorm hidden (the superstep tap row,
    ``model.decode_step_tap``) becomes one training row; the row's label is
    whether the *whole rollout* reached the correct answer — the probe
    learns to read "this trajectory will land" from the hidden state, the
    step-level early signal of PAPERS.md's hidden-state pruning line.

    Returns (taps [N, d_model] f32, labels [N] f32 in {0, 1}).
    """
    from .model import decode_step_tap, prefill  # local import to keep top light

    samples = datagen.generate(dataset, n, seed)
    pre = jax.jit(lambda p, t, l: prefill(cfg, p, t, l))
    dec = jax.jit(
        lambda p, tok, pos, kc, vc: decode_step_tap(cfg, p, tok, pos, kc, vc, use_pallas=False)
    )
    taps: list[np.ndarray] = []
    labels: list[float] = []
    for s in samples:
        ids, length = tokenizer.encode_prompt(s.prompt(), cfg.prompt_len)
        logits, kc, vc = pre(params, jnp.asarray([ids], jnp.int32), jnp.int32(length))
        out = []
        rollout_taps = []
        pos = length
        tok = int(jnp.argmax(logits[0]))
        for _ in range(max_new):
            if tok == tokenizer.EOS_ID or pos >= cfg.max_seq:
                break
            out.append(tok)
            logits, tap, kc, vc = dec(params, jnp.asarray([tok], jnp.int32), jnp.int32(pos), kc, vc)
            rollout_taps.append(np.asarray(tap[0], np.float32))
            pos += 1
            tok = int(jnp.argmax(logits[0]))
        label = 1.0 if f"#### {s.answer}" in tokenizer.decode(out) else 0.0
        taps.extend(rollout_taps)
        labels.extend([label] * len(rollout_taps))
    if not taps:
        return np.zeros((0, cfg.d_model), np.float32), np.zeros((0,), np.float32)
    return np.stack(taps), np.asarray(labels, np.float32)


def fit_probe(cfg: ModelConfig, params, *, n: int = 60, seed: int = 31, steps: int = 400, lr: float = 0.5, max_new: int = 80):
    """Fit the tiny linear pruning probe on tapped rollouts.

    Logistic regression (hand-rolled full-batch gradient descent — no
    sklearn in the image) over standardized tap rows from both synthetic
    datasets; the standardization is folded into the final weights so the
    runtime applies a bare affine score ``sigmoid(w · tap + b)``.

    Returns the probe-artifact dict ``aot.py`` serializes as
    ``probe_{m}.json``: d_model, w [d_model], b, rows, train_acc.
    """
    xs, ys = [], []
    for i, ds in enumerate(("gsm_synth", "math_synth")):
        x, y = collect_tap_rollouts(cfg, params, ds, n=n, seed=seed + 17 * i, max_new=max_new)
        xs.append(x)
        ys.append(y)
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    rows = int(x.shape[0])
    if rows == 0:
        return {"d_model": cfg.d_model, "w": [0.0] * cfg.d_model, "b": 0.0, "rows": 0, "train_acc": 0.0}

    mu = x.mean(axis=0)
    sd = x.std(axis=0) + 1e-6
    xn = (x - mu) / sd
    w = np.zeros(cfg.d_model, np.float64)
    b = 0.0
    for _ in range(steps):
        z = xn @ w + b
        p = 1.0 / (1.0 + np.exp(-z))
        g = p - y
        w -= lr * (xn.T @ g / rows + 1e-4 * w)
        b -= lr * float(g.mean())
    acc = float(((xn @ w + b > 0) == (y > 0.5)).mean())
    # Fold standardization into the shipped affine form: w'·x + b' == w·xn + b.
    w_raw = w / sd
    b_raw = b - float(w_raw @ mu)
    return {
        "d_model": cfg.d_model,
        "w": [float(v) for v in w_raw],
        "b": float(b_raw),
        "rows": rows,
        "train_acc": acc,
    }
