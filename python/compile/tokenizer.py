"""Character-level tokenizer shared between the Python compile path and the
Rust serving path.

The vocabulary is a *fixed contract*: the Rust tokenizer
(``rust/src/tokenizer/mod.rs``) re-implements exactly this mapping, and the
AOT manifest embeds ``VOCAB_CHARS`` so the Rust side can verify agreement at
startup. Any change here is an artifact-breaking change.

Layout:
  id 0 = <pad>, id 1 = <bos>, id 2 = <eos>,
  ids 3.. = ``VOCAB_CHARS[i - 3]``,
  remaining ids up to ``VOCAB_SIZE`` are unused padding slots (so the model's
  logit dimension is a friendly power of two for the Pallas kernels).
"""

from __future__ import annotations

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
NUM_SPECIALS = 3

# 51 printable characters; everything the synthetic datasets emit.
VOCAB_CHARS = "\n 0123456789+-*/=().,?#%:abcdefghijklmnopqrstuvwxyz'"

# Logit dimension (power of two; last ids are unused).
VOCAB_SIZE = 64

_CHAR_TO_ID = {c: i + NUM_SPECIALS for i, c in enumerate(VOCAB_CHARS)}
_ID_TO_CHAR = {i + NUM_SPECIALS: c for i, c in enumerate(VOCAB_CHARS)}

assert len(VOCAB_CHARS) + NUM_SPECIALS <= VOCAB_SIZE


def encode(text: str) -> list[int]:
    """Encode ``text`` to token ids. Raises on out-of-vocabulary chars."""
    try:
        return [_CHAR_TO_ID[c] for c in text]
    except KeyError as e:  # pragma: no cover - guarded by dataset generators
        raise ValueError(f"out-of-vocabulary character: {e.args[0]!r}") from None


def decode(ids) -> str:
    """Decode token ids to text, skipping specials and unused slots."""
    return "".join(_ID_TO_CHAR.get(int(i), "") for i in ids)


def encode_prompt(text: str, max_len: int) -> tuple[list[int], int]:
    """BOS + text, padded with PAD to ``max_len``. Returns (ids, true_len)."""
    ids = [BOS_ID] + encode(text)
    if len(ids) > max_len:
        raise ValueError(f"prompt too long: {len(ids)} > {max_len}")
    true_len = len(ids)
    ids = ids + [PAD_ID] * (max_len - len(ids))
    return ids, true_len
