"""Fused latent-informativeness signal kernel (Pallas, L1).

One VMEM-resident pass over a ``[block_b, V]`` tile of branch logits
computes all three of the paper's per-step signals simultaneously:

  KL(p‖q)   — information content vs the unconditional reference q,
  confidence — max_v p(v),
  entropy    — -Σ p log(p+ε),

instead of four separate softmax/max/entropy/KL lowerings. On a real TPU
this saves ~4× the HBM reads of the logits tensor (the tile plus the q row
fit trivially in VMEM: 32×64 f32 = 8 KiB + 256 B); the reductions run on
the VPU. On this image the kernel is lowered with ``interpret=True`` so it
becomes plain HLO and runs on the CPU PJRT client — the *structure*
(single fused pass, row-wise reductions, [-3,3]-safe numerics) is what we
validate; TPU perf is estimated in DESIGN.md §7.

Contract mirrored by ``ref.signals_ref`` and asserted in
``python/tests/test_signals.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS


def _signals_kernel(logits_ref, q_ref, kl_ref, conf_ref, ent_ref):
    """Kernel body: one [block_b, V] tile → three [block_b] outputs."""
    x = logits_ref[...].astype(jnp.float32)  # [bb, V]
    q = q_ref[...].astype(jnp.float32)  # [V]

    # Stable log-softmax of the branch rows.
    m = jnp.max(x, axis=-1, keepdims=True)
    sx = x - m
    lse = jnp.log(jnp.sum(jnp.exp(sx), axis=-1, keepdims=True))
    logp = sx - lse
    p = jnp.exp(logp)

    # Stable log-softmax of the reference row (recomputed per tile; it is a
    # 64-float vector, cheaper to recompute on the VPU than to stage).
    qm = jnp.max(q)
    sq = q - qm
    logq = sq - jnp.log(jnp.sum(jnp.exp(sq)))

    kl_ref[...] = jnp.sum(p * (logp - logq[None, :]), axis=-1)
    conf_ref[...] = jnp.max(p, axis=-1)
    ent_ref[...] = -jnp.sum(p * jnp.log(p + EPS), axis=-1)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def signals(logits: jax.Array, q_logits: jax.Array, *, block_b: int = 32, interpret: bool = True):
    """Fused (KL, confidence, entropy) over branch logits.

    Args:
      logits:   [B, V] float — per-branch next-token logits.
      q_logits: [V] float — unconditional reference logits.
      block_b:  branch-tile size (grid dimension).
      interpret: lower the Pallas kernel in interpret mode (required for
        CPU-PJRT execution; see DESIGN.md §Hardware-Adaptation).

    Returns:
      (kl, confidence, entropy), each [B] float32.
    """
    b, v = logits.shape
    bb = min(block_b, b)
    if b % bb != 0:  # pad to a whole number of tiles
        pad = (-b) % bb
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
    padded_b = logits.shape[0]

    grid = (padded_b // bb,)
    kl, conf, ent = pl.pallas_call(
        _signals_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
            pl.BlockSpec((v,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded_b,), jnp.float32),
            jax.ShapeDtypeStruct((padded_b,), jnp.float32),
            jax.ShapeDtypeStruct((padded_b,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, q_logits)
    return kl[:b], conf[:b], ent[:b]
