"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations that the Pallas kernels in
``signals.py`` and ``attention.py`` are tested against (``pytest
python/tests``). They are also importable by ``model.py`` through the
``use_pallas=False`` escape hatch so the whole L2 graph can be built without
Pallas for differential testing.

Numerics follow the paper's Algorithm 2 exactly:
  confidence  C = max_v p(v)
  entropy     H = -sum_v p(v) * log(p(v) + eps)
  KL          D = KL(p || q) = sum_v p(v) * (log p(v) - log q(v))
with p = softmax(logits), q = softmax(q_logits).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-9


def log_softmax(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    s = x - m
    return s - jnp.log(jnp.sum(jnp.exp(s), axis=axis, keepdims=True))


def signals_ref(logits: jax.Array, q_logits: jax.Array):
    """Reference latent-informativeness signals.

    Args:
      logits:   [B, V] next-token logits per branch.
      q_logits: [V] unconditional (BOS-context) reference logits.

    Returns:
      (kl [B], confidence [B], entropy [B]) all float32.
    """
    logp = log_softmax(logits.astype(jnp.float32))
    p = jnp.exp(logp)
    logq = log_softmax(q_logits.astype(jnp.float32))
    kl = jnp.sum(p * (logp - logq[None, :]), axis=-1)
    conf = jnp.max(p, axis=-1)
    ent = -jnp.sum(p * jnp.log(p + EPS), axis=-1)
    return kl, conf, ent


def decode_attention_ref(q, k, v, pos):
    """Reference single-query attention over a KV cache.

    Args:
      q:   [B, H, Dh] query for the current position.
      k:   [B, H, S, Dh] key cache (slots > pos are garbage).
      v:   [B, H, S, Dh] value cache.
      pos: scalar int32 — current position; keys at slot j are valid iff
           j <= pos.

    Returns:
      [B, H, Dh] attention output.
    """
    s = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    mask = jnp.arange(s)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", w, v)
