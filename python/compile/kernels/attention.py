"""Fused single-query decode attention kernel (Pallas, L1).

The decode hot-spot: at each generation step every alive branch attends its
single new query against the whole KV cache. Rethought for TPU-style
Pallas (DESIGN.md §Hardware-Adaptation):

- grid = (H,): one program instance per head; each program streams the
  whole branch-batch tile for its head — q [B, Dh], K/V [B, S, Dh] — into
  VMEM (B·S·Dh·4 B ≈ 0.7 MiB/head at B=32, S=224, Dh=32: comfortably
  resident) and computes the masked online softmax + p·V contraction for
  all branches at once. The q·Kᵀ and p·V products are the MXU work on
  real hardware.
- masking uses an additive bias row (0 for slots ≤ pos, -1e30 beyond),
  precomputed in the L2 graph, so the kernel needs no scalar plumbing.

Why per-head rather than per-(branch, head): Pallas `interpret=True`
lowers the grid to a *sequential* XLA while-loop; a (B, H) grid costs
B·H loop iterations each carrying full-array copies (measured 335 ms per
decode step at B=32 on the CPU testbed — see EXPERIMENTS.md §Perf). A
per-head grid keeps the same VMEM story on TPU (streaming K/V tiles per
program) while the batch dimension stays vectorized VPU/MXU work.

Lowered with ``interpret=True`` for CPU-PJRT execution; numerics asserted
against ``ref.decode_attention_ref`` in ``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale: float):
    """One head: q [B, Dh], K/V [B, S, Dh], bias [S] → out [B, Dh]."""
    q = q_ref[...].astype(jnp.float32)  # [B, Dh]
    k = k_ref[...].astype(jnp.float32)  # [B, S, Dh]
    v = v_ref[...].astype(jnp.float32)  # [B, S, Dh]
    bias = bias_ref[...].astype(jnp.float32)  # [S]

    # q·Kᵀ for every branch of this head (MXU contraction on TPU).
    scores = jnp.einsum("bsd,bd->bs", k, q) * scale + bias[None, :]  # [B, S]
    m = jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores - m)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    o_ref[...] = jnp.einsum("bs,bsd->bd", w, v) / denom  # [B, Dh]


def _decode_attn_kernel_packed(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale: float):
    """One head, per-row mask: q [B, Dh], K/V [B, S, Dh], bias [B, S].

    Identical arithmetic to ``_decode_attn_kernel`` except the additive
    mask row differs per branch — the cross-request packed batch puts
    branches of different requests (different sequence positions) in one
    bucket, so each row carries its own visibility horizon. Row-wise the
    op sequence is the same, which is what keeps a packed row bitwise
    equal to the same row decoded in a solo-request dispatch.
    """
    q = q_ref[...].astype(jnp.float32)  # [B, Dh]
    k = k_ref[...].astype(jnp.float32)  # [B, S, Dh]
    v = v_ref[...].astype(jnp.float32)  # [B, S, Dh]
    bias = bias_ref[...].astype(jnp.float32)  # [B, S]

    scores = jnp.einsum("bsd,bd->bs", k, q) * scale + bias  # [B, S]
    m = jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores - m)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    o_ref[...] = jnp.einsum("bs,bsd->bd", w, v) / denom  # [B, Dh]


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, bias, *, interpret: bool = True):
    """Fused masked single-query attention over the KV cache.

    Args:
      q:    [B, H, Dh] current-step queries.
      k:    [B, H, S, Dh] key cache.
      v:    [B, H, S, Dh] value cache.
      bias: [S] additive mask row (0 where slot ≤ pos, -1e30 beyond). Shared
        by all branches: every branch of a request sits at the same
        position, which is what makes the fixed-shape bucket batching of
        the Rust engine sound.
      interpret: Pallas interpret mode (mandatory on CPU PJRT).

    Returns:
      [B, H, Dh] attention outputs (float32).
    """
    b, h, s, dh = k.shape
    scale = 1.0 / float(dh) ** 0.5
    kernel = functools.partial(_decode_attn_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            # None dims are squeezed away inside the kernel body; the grid
            # index j selects the head.
            pl.BlockSpec((b, None, dh), lambda j: (0, j, 0)),
            pl.BlockSpec((b, None, s, dh), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((b, None, s, dh), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((s,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, None, dh), lambda j: (0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=interpret,
    )(q, k, v, bias)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_packed(q, k, v, bias, *, interpret: bool = True):
    """[`decode_attention`] with a **per-row** additive mask.

    Args:
      q:    [B, H, Dh] current-step queries.
      k:    [B, H, S, Dh] key cache.
      v:    [B, H, S, Dh] value cache.
      bias: [B, S] additive mask, one row per branch (0 where slot ≤ that
        row's pos, -1e30 beyond). This is the cross-request batch-fusion
        variant: rows of one bucket may belong to different requests at
        different sequence positions, so the visibility horizon is
        per-row instead of shared.
      interpret: Pallas interpret mode (mandatory on CPU PJRT).

    Returns:
      [B, H, Dh] attention outputs (float32).
    """
    b, h, s, dh = k.shape
    scale = 1.0 / float(dh) ** 0.5
    kernel = functools.partial(_decode_attn_kernel_packed, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((b, None, dh), lambda j: (0, j, 0)),
            pl.BlockSpec((b, None, s, dh), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((b, None, s, dh), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((b, s), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, None, dh), lambda j: (0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=interpret,
    )(q, k, v, bias)
    return out
