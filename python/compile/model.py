"""L2: GPT-style decoder-only transformer in JAX (build-time only).

Defines the compute graphs that are AOT-lowered to HLO text by ``aot.py``
and executed from the Rust engine via PJRT:

- ``prefill(params, tokens[B,P], length)`` — full causal pass over the
  (padded) prompt; returns last-real-token logits and the primed KV caches.
- ``decode_step(params, token[B], pos, k_cache, v_cache)`` — one
  autoregressive step for every branch in the batch; calls the Pallas
  decode-attention kernel (L1) and returns logits + updated caches.

Model-size roles (paper substitution, DESIGN.md §2):
- ``sm`` plays DeepSeek-R1-Distill-Qwen-1.5B (weaker reasoner),
- ``lg`` plays Qwen2.5-7B-Instruct (stronger reasoner).

Parameters are a flat ``dict[str, jax.Array]`` with deterministic ordering
(``param_names``) — the same order in which ``aot.py`` writes ``weights.bin``
and in which the Rust runtime feeds buffers to the executables.

KV-cache layout: ``[layers, B, heads, max_seq, head_dim]`` float32. All
branches of a request share the same position (they start from one prompt
and step in lockstep), so ``pos`` is a scalar in ``decode_step``. The
cross-request batch-fusion variant ``decode_step_packed`` generalizes
``pos`` to a per-row vector so branches of *different* requests (different
prompts, different positions) can share one bucketed dispatch;
``fuse_rows`` is the companion op that admits a freshly prefilled request
into a shared pod cache.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import tokenizer
from .kernels import ref as kref
from .kernels.attention import decode_attention, decode_attention_packed


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for one model size."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    max_seq: int = 224
    prompt_len: int = 96
    vocab: int = tokenizer.VOCAB_SIZE
    ffn_mult: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ffn(self) -> int:
        return self.d_model * self.ffn_mult

    def param_shapes(self) -> dict[str, tuple[int, ...]]:
        """Deterministic (insertion-ordered) name → shape map."""
        d, v, s, f = self.d_model, self.vocab, self.max_seq, self.d_ffn
        shapes: dict[str, tuple[int, ...]] = {
            "tok_emb": (v, d),
            "pos_emb": (s, d),
        }
        for i in range(self.n_layers):
            p = f"layer{i}."
            shapes[p + "ln1_g"] = (d,)
            shapes[p + "ln1_b"] = (d,)
            shapes[p + "wq"] = (d, d)
            shapes[p + "wk"] = (d, d)
            shapes[p + "wv"] = (d, d)
            shapes[p + "wo"] = (d, d)
            shapes[p + "ln2_g"] = (d,)
            shapes[p + "ln2_b"] = (d,)
            shapes[p + "w1"] = (d, f)
            shapes[p + "b1"] = (f,)
            shapes[p + "w2"] = (f, d)
            shapes[p + "b2"] = (d,)
        shapes["lnf_g"] = (d,)
        shapes["lnf_b"] = (d,)
        shapes["head"] = (d, v)
        return shapes

    def param_names(self) -> list[str]:
        return list(self.param_shapes().keys())

    def n_params(self) -> int:
        return sum(math.prod(s) for s in self.param_shapes().values())


# The two model sizes used throughout the repo (see DESIGN.md §2).
CONFIGS: dict[str, ModelConfig] = {
    # Sized for the single-core CPU testbed (see DESIGN.md §2): "sm" plays
    # the weak reasoner (DeepSeek-1.5B role), "lg" the strong one (Qwen-7B
    # role). What matters for the paper's claims is the capability *gap*.
    "sm": ModelConfig(name="sm", d_model=96, n_layers=2, n_heads=4),
    "lg": ModelConfig(name="lg", d_model=160, n_layers=3, n_heads=5),
}

# Batch buckets the Rust engine compacts alive branch sets into.
BATCH_BUCKETS = (1, 2, 4, 8, 16, 32)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jax.Array]:
    """Scaled-normal init (GPT-2 style: residual projections down-scaled)."""
    params = {}
    shapes = cfg.param_shapes()
    keys = jax.random.split(key, len(shapes))
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    for (name, shape), k in zip(shapes.items(), keys):
        if name.endswith(("_g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", "b1", "b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            std = 0.02
            if name.endswith(("wo", "w2")):
                std *= resid_scale
            params[name] = std * jax.random.normal(k, shape, jnp.float32)
    return params


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _split_heads(x, n_heads):  # [..., d] -> [..., H, Dh]
    return x.reshape(*x.shape[:-1], n_heads, x.shape[-1] // n_heads)


def prefill(cfg: ModelConfig, params, tokens, length):
    """Full causal pass over the padded prompt.

    Args:
      tokens: [B, P] int32, BOS + prompt chars, PAD beyond ``length``.
      length: scalar int32 — true prompt length (shared across the batch:
        branches replicate one request's prompt).

    Returns:
      logits [B, V] at position ``length - 1``,
      k_cache, v_cache [L, B, H, S, Dh] primed in slots [0, P).
    """
    b, p = tokens.shape
    h, dh, s = cfg.n_heads, cfg.head_dim, cfg.max_seq
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :p, :]

    causal = jnp.tril(jnp.ones((p, p), jnp.bool_))
    bias = jnp.where(causal, 0.0, -1e30)[None, None, :, :]  # [1,1,P,P]
    scale = 1.0 / math.sqrt(dh)

    k_cache = jnp.zeros((cfg.n_layers, b, h, s, dh), jnp.float32)
    v_cache = jnp.zeros((cfg.n_layers, b, h, s, dh), jnp.float32)

    for i in range(cfg.n_layers):
        pref = f"layer{i}."
        hdd = _ln(x, params[pref + "ln1_g"], params[pref + "ln1_b"])
        q = _split_heads(hdd @ params[pref + "wq"], h)  # [B,P,H,Dh]
        k = _split_heads(hdd @ params[pref + "wk"], h)
        v = _split_heads(hdd @ params[pref + "wv"], h)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale + bias
        w = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, p, cfg.d_model)
        x = x + att @ params[pref + "wo"]
        hdd = _ln(x, params[pref + "ln2_g"], params[pref + "ln2_b"])
        x = x + (jax.nn.gelu(hdd @ params[pref + "w1"] + params[pref + "b1"])) @ params[pref + "w2"] + params[pref + "b2"]

        k_cache = k_cache.at[i, :, :, :p, :].set(k.transpose(0, 2, 1, 3))
        v_cache = v_cache.at[i, :, :, :p, :].set(v.transpose(0, 2, 1, 3))

    x = _ln(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["head"]  # [B, P, V]
    last = jnp.take_along_axis(
        logits, (length - 1)[None, None, None].astype(jnp.int32).repeat(b, 0), axis=1
    )[:, 0, :]
    return last, k_cache, v_cache


def _decode_body(cfg: ModelConfig, params, token, pos, k_cache, v_cache, *, use_pallas=True):
    """Shared decode-step body: everything up to (and including) the final
    layernorm. Both ``decode_step`` and ``decode_step_tap`` call this so
    the two graphs perform the same ops in the same order — the tapped
    artifact's logits and caches are bitwise identical to the untapped
    one (``test_superstep_tap.py`` pins it).

    Returns post-``lnf`` hidden ``x`` [B, d] and the updated caches.
    """
    b = token.shape[0]
    h, dh, s = cfg.n_heads, cfg.head_dim, cfg.max_seq
    x = params["tok_emb"][token] + params["pos_emb"][pos]  # [B, d]

    # Additive mask row shared by all branches: slots <= pos are visible
    # (the new K/V is written at slot pos before attention).
    bias = jnp.where(jnp.arange(s) <= pos, 0.0, -1e30).astype(jnp.float32)

    for i in range(cfg.n_layers):
        pref = f"layer{i}."
        hdd = _ln(x, params[pref + "ln1_g"], params[pref + "ln1_b"])
        q = _split_heads(hdd @ params[pref + "wq"], h)  # [B,H,Dh]
        k = _split_heads(hdd @ params[pref + "wk"], h)
        v = _split_heads(hdd @ params[pref + "wv"], h)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None, :, :, None, :], (i, 0, 0, pos, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None, :, :, None, :], (i, 0, 0, pos, 0)
        )
        if use_pallas:
            att = decode_attention(q, k_cache[i], v_cache[i], bias)
        else:
            att = kref.decode_attention_ref(q, k_cache[i], v_cache[i], pos)
        x = x + att.reshape(b, cfg.d_model) @ params[pref + "wo"]
        hdd = _ln(x, params[pref + "ln2_g"], params[pref + "ln2_b"])
        x = x + (jax.nn.gelu(hdd @ params[pref + "w1"] + params[pref + "b1"])) @ params[pref + "w2"] + params[pref + "b2"]

    x = _ln(x, params["lnf_g"], params["lnf_b"])
    return x, k_cache, v_cache


def decode_step(cfg: ModelConfig, params, token, pos, k_cache, v_cache, *, use_pallas=True):
    """One autoregressive step for every branch in the bucket.

    Args:
      token: [B] int32 — tokens sampled at the previous step.
      pos:   scalar int32 — slot this step writes (== current seq length).
      k_cache, v_cache: [L, B, H, S, Dh].
      use_pallas: route attention through the L1 Pallas kernel (default) or
        the pure-jnp oracle (differential testing).

    Returns:
      logits [B, V], updated caches.
    """
    x, k_cache, v_cache = _decode_body(
        cfg, params, token, pos, k_cache, v_cache, use_pallas=use_pallas
    )
    return x @ params["head"], k_cache, v_cache


def decode_step_tap(cfg: ModelConfig, params, token, pos, k_cache, v_cache, *, use_pallas=True):
    """``decode_step`` plus the **hidden-state tap**: the post-final-
    layernorm hidden row per branch, exported for learned pruning probes
    ("Hidden States as Early Signals"). The tap is the exact intermediate
    the head projection consumes — no extra compute, one extra output —
    so logits/caches remain bitwise identical to the untapped step.

    Returns:
      logits [B, V], tap [B, d], updated caches.
    """
    x, k_cache, v_cache = _decode_body(
        cfg, params, token, pos, k_cache, v_cache, use_pallas=use_pallas
    )
    return x @ params["head"], x, k_cache, v_cache


def decode_step_packed(cfg: ModelConfig, params, token, pos, k_cache, v_cache):
    """One autoregressive step for a **cross-request packed** bucket.

    The batch-fusion variant of ``decode_step``: rows of the bucket may
    belong to different requests, each at its own sequence position, so
    ``pos`` is an ``[B]`` int32 vector instead of a scalar. Every
    computation is row-local (the per-row position embedding, the per-row
    K/V write, the per-row masked attention, and the row-wise MLP), which
    is what makes a packed row bitwise equal to the same row decoded in a
    solo-request dispatch — ``python/tests/test_packed.py`` pins that
    parity and the Rust engine's fused scheduler relies on it.

    Rows that carry no live branch this step (free pod rows, or leased
    rows whose request did not stage a token this tick) are driven with
    ``token = PAD`` and that row's **current** (not-yet-written) position:
    the k/v garbage they write lands in a slot that is either overwritten
    by the row's next real decode before it is ever attended over, or
    belongs to a row whose outputs are never read again.

    Args:
      token: [B] int32 — tokens sampled at the previous step (PAD for
        rows without a live branch).
      pos:   [B] int32 — per-row slot this step writes.
      k_cache, v_cache: [L, B, H, S, Dh].

    Returns:
      logits [B, V], updated caches.
    """
    x, k_cache, v_cache = _decode_body_packed(cfg, params, token, pos, k_cache, v_cache)
    return x @ params["head"], k_cache, v_cache


def decode_step_packed_tap(cfg: ModelConfig, params, token, pos, k_cache, v_cache):
    """``decode_step_packed`` plus the hidden-state tap (see
    ``decode_step_tap``): same shared body, one extra output, logits and
    caches bitwise identical to the untapped packed step.

    Returns:
      logits [B, V], tap [B, d], updated caches.
    """
    x, k_cache, v_cache = _decode_body_packed(cfg, params, token, pos, k_cache, v_cache)
    return x @ params["head"], x, k_cache, v_cache


def _decode_body_packed(cfg: ModelConfig, params, token, pos, k_cache, v_cache):
    """Shared packed decode-step body (see ``_decode_body``): returns the
    post-``lnf`` hidden ``x`` [B, d] and the updated caches."""
    b = token.shape[0]
    h, dh, s = cfg.n_heads, cfg.head_dim, cfg.max_seq
    x = params["tok_emb"][token] + params["pos_emb"][pos]  # [B, d]

    # Per-row additive mask: slots <= pos[r] visible for row r.
    bias = jnp.where(jnp.arange(s)[None, :] <= pos[:, None], 0.0, -1e30).astype(jnp.float32)

    def write_row(row_cache, kr, p):  # [H, S, Dh], [H, Dh], scalar pos
        return jax.lax.dynamic_update_slice(row_cache, kr[:, None, :], (0, p, 0))

    for i in range(cfg.n_layers):
        pref = f"layer{i}."
        hdd = _ln(x, params[pref + "ln1_g"], params[pref + "ln1_b"])
        q = _split_heads(hdd @ params[pref + "wq"], h)  # [B,H,Dh]
        k = _split_heads(hdd @ params[pref + "wk"], h)
        v = _split_heads(hdd @ params[pref + "wv"], h)
        # Row-wise K/V write at each row's own position (vmapped
        # dynamic_update_slice == the scalar-pos write, per row).
        k_cache = k_cache.at[i].set(jax.vmap(write_row)(k_cache[i], k, pos))
        v_cache = v_cache.at[i].set(jax.vmap(write_row)(v_cache[i], v, pos))
        att = decode_attention_packed(q, k_cache[i], v_cache[i], bias)
        x = x + att.reshape(b, cfg.d_model) @ params[pref + "wo"]
        hdd = _ln(x, params[pref + "ln2_g"], params[pref + "ln2_b"])
        x = x + (jax.nn.gelu(hdd @ params[pref + "w1"] + params[pref + "b1"])) @ params[pref + "w2"] + params[pref + "b2"]

    x = _ln(x, params["lnf_g"], params["lnf_b"])
    return x, k_cache, v_cache


def compact_rows(k_dst, v_dst, k_src, v_src, idx):
    """Gather a pod's live rows into a smaller-bucket pod cache.

    The pod-compaction companion of ``fuse_rows``: after sustained
    pruning a pod's live rows occupy a fraction of its bucket, and this
    op pulls exactly those rows into a smaller destination cache in one
    device call so the big pod's allocation can be dropped. ``idx`` is a
    ``[D]`` int32 vector over the *destination* rows: row ``r`` of the
    result is the **source** pod's row ``idx[r]`` when ``idx[r] >= 0``,
    or the destination's own row ``r`` (a free row whose stale contents
    are harmless — admission overwrites free rows wholly) when
    ``idx[r] < 0``.

    The destination k/v are the donated operands in the AOT export
    (``aot.lower_compact``): the outputs alias them exactly the way the
    decode/superstep successors alias their k/v inputs, so on real
    hardware compaction writes straight into the smaller pod's buffers.

    Args:
      k_dst, v_dst: [L, D, H, S, Dh] — the smaller destination cache.
      k_src, v_src: [L, B, H, S, Dh] — the pod being compacted (B >= D).
      idx: [D] int32 source-row selector (see above).

    Returns:
      compacted (k, v), both [L, D, H, S, Dh].
    """
    take_src = (idx >= 0)[None, :, None, None, None]
    sel = jnp.clip(idx, 0, k_src.shape[1] - 1)
    k = jnp.where(take_src, jnp.take(k_src, sel, axis=1), k_dst)
    v = jnp.where(take_src, jnp.take(v_src, sel, axis=1), v_dst)
    return k, v


def fuse_rows(k_dst, v_dst, k_src, v_src, idx):
    """Merge a freshly prefilled bucket-1 cache into a shared pod cache.

    ``idx`` is an ``[B]`` int32 vector: row ``r`` of the result is the
    pod's own row ``idx[r]`` when ``idx[r] >= 0``, or row 0 of the source
    (the new request's prompt cache) when ``idx[r] < 0`` — one dispatch
    both broadcasts the prompt across the request's leased rows and
    leaves every other resident row untouched.

    Args:
      k_dst, v_dst: [L, B, H, S, Dh] — the pod cache.
      k_src, v_src: [L, 1, H, S, Dh] — the prefill cache being admitted.
      idx: [B] int32 row selector (see above).

    Returns:
      merged (k, v), both [L, B, H, S, Dh].
    """
    take_src = (idx < 0)[None, :, None, None, None]
    keep = jnp.clip(idx, 0, k_dst.shape[1] - 1)
    k = jnp.where(take_src, k_src, jnp.take(k_dst, keep, axis=1))
    v = jnp.where(take_src, v_src, jnp.take(v_dst, keep, axis=1))
    return k, v


def fork_rows(k_dst, v_dst, k_src, v_src, idx):
    """Copy-on-write fork: broadcast shared-prefix rows into pod rows.

    The prefix-sharing companion of ``fuse_rows``/``compact_rows``: a
    prompt prefix is prefilled **once** into a bucket-1 store entry, and
    admission forks it into a request's leased pod rows in one device
    call instead of re-running prefill per request. ``idx`` is a ``[D]``
    int32 vector over the *destination* rows: row ``r`` of the result is
    the **source** (shared prefix) row ``idx[r]`` when ``idx[r] >= 0``,
    or the destination's own row ``r`` (a resident or free row, left
    untouched) when ``idx[r] < 0``.

    Donation contract (``aot.lower_fork``): the destination k/v are the
    donated operands — outputs alias them exactly like ``compact_rows``
    — while the source is **never** donated: the shared prefix entry
    stays live in the store for the next reader. The divergence point is
    the first decode after the fork: each forked row's subsequent K/V
    writes land in its own (donated) pod row, never back in the shared
    entry, which is what makes the copy-on-write safe.

    Args:
      k_dst, v_dst: [L, D, H, S, Dh] — the pod cache being written.
      k_src, v_src: [L, B, H, S, Dh] — the shared prefix entry (B = 1 in
        the exported pairs; the formula is bucket-generic).
      idx: [D] int32 source-row selector (see above).

    Returns:
      forked (k, v), both [L, D, H, S, Dh].
    """
    take_src = (idx >= 0)[None, :, None, None, None]
    sel = jnp.clip(idx, 0, k_src.shape[1] - 1)
    k = jnp.where(take_src, jnp.take(k_src, sel, axis=1), k_dst)
    v = jnp.where(take_src, jnp.take(v_src, sel, axis=1), v_dst)
    return k, v


def forward_train(cfg: ModelConfig, params, tokens):
    """Teacher-forced logits over a [B, T] batch (training only, no cache)."""
    b, t = tokens.shape
    h, dh = cfg.n_heads, cfg.head_dim
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
    causal = jnp.tril(jnp.ones((t, t), jnp.bool_))
    bias = jnp.where(causal, 0.0, -1e30)[None, None, :, :]
    scale = 1.0 / math.sqrt(dh)
    for i in range(cfg.n_layers):
        pref = f"layer{i}."
        hdd = _ln(x, params[pref + "ln1_g"], params[pref + "ln1_b"])
        q = _split_heads(hdd @ params[pref + "wq"], h)
        k = _split_heads(hdd @ params[pref + "wk"], h)
        v = _split_heads(hdd @ params[pref + "wv"], h)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale + bias
        w = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, t, cfg.d_model)
        x = x + att @ params[pref + "wo"]
        hdd = _ln(x, params[pref + "ln2_g"], params[pref + "ln2_b"])
        x = x + (jax.nn.gelu(hdd @ params[pref + "w1"] + params[pref + "b1"])) @ params[pref + "w2"] + params[pref + "b2"]
    x = _ln(x, params["lnf_g"], params["lnf_b"])
    return x @ params["head"]
