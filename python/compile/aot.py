"""AOT exporter: train → lower → serialize. The single Python entry point
(``make artifacts`` runs ``python -m compile.aot``); after it finishes the
Rust binary is self-contained.

Exports, per model size m ∈ {sm, lg}:

  artifacts/prefill_{m}_b1.hlo.txt        prompt pass (branches share prompts)
  artifacts/decode_{m}_b{B}.hlo.txt       one step per batch bucket B
  artifacts/superstep_{m}_b{B}.hlo.txt    fused decode+signals superstep: one
                                          dispatch runs the forward pass AND
                                          scores the fresh logits against the
                                          device-resident q, so gated tokens
                                          never re-upload the logits slab
  artifacts/gather_{m}_b{S}to{D}.hlo.txt  KV-cache gather: branch broadcast
                                          (S=1) and post-prune compaction
  artifacts/decode_packed_{m}_b{B}.hlo.txt    cross-request packed decode:
                                          per-row ``pos`` vector so branches
                                          of different requests share one
                                          bucketed dispatch
  artifacts/superstep_packed_{m}_b{B}.hlo.txt packed decode+signals superstep
                                          (the fused scheduler's hot path)
  artifacts/fuse_{m}_b{B}.hlo.txt         pod admission: merge a prefilled
                                          bucket-1 cache into a shared pod
                                          cache's leased rows
  artifacts/compact_{m}_b{S}to{D}.hlo.txt pod compaction: gather a pod's
                                          live rows into a smaller-bucket
                                          pod cache in one device call,
                                          with the destination k/v donated
                                          (same alias-table contract as
                                          the decode/superstep families)
  artifacts/fork_{m}_b{S}to{D}.hlo.txt    prefix-sharing copy-on-write
                                          fork: broadcast a shared
                                          bucket-1 prefix entry into a
                                          pod's leased rows in one device
                                          call, destination k/v donated
                                          (same alias-table contract as
                                          compact); the source entry is
                                          never donated — it stays in the
                                          prefix store for later readers
  artifacts/weights_{m}.bin               flat little-endian f32 params
plus model-independent:
  artifacts/signals_b{B}.hlo.txt          fused Pallas KL/conf/entropy kernel
  artifacts/manifest.json                 the contract consumed by Rust

Interchange is **HLO text**, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import tokenizer, train
from .kernels.signals import signals
from .model import (
    BATCH_BUCKETS,
    CONFIGS,
    ModelConfig,
    compact_rows,
    decode_step,
    decode_step_packed,
    decode_step_packed_tap,
    decode_step_tap,
    fork_rows,
    fuse_rows,
    prefill,
)

FORMAT_VERSION = 1


def superstep(cfg: ModelConfig, params: dict, token, pos, k_cache, v_cache, q_logits):
    """Fused decode→signals superstep: one dispatch per gated token.

    Chains ``model.decode_step`` into ``kernels.signals.signals`` so the
    freshly produced ``[B, V]`` logits are scored on-device against the
    device-resident reference ``q`` — the logits never cross the host
    boundary between decoding and scoring. Returns
    ``(logits, kl, conf, ent, k_cache, v_cache)``; the runtime downloads
    the logits once (for sampling) and the three ``[B]`` signal vectors,
    and donates the predecessor k/v buffers into the successor cache.
    """
    logits, k_cache, v_cache = decode_step(
        cfg, params, token, pos, k_cache, v_cache, use_pallas=True
    )
    kl, conf, ent = signals(logits, q_logits)
    return logits, kl, conf, ent, k_cache, v_cache


def lower_superstep(cfg: ModelConfig, b: int, donate: bool = True):
    """Lower the fused superstep for bucket ``b`` with **compile-time k/v
    donation**.

    The runtime layer donates the predecessor k/v buffers on every
    superstep dispatch (``execute_b_donated``); ``donate_argnums`` here
    mirrors that contract into the exported HLO as an
    ``input_output_alias`` config (exactly what ``jax.jit``'s donation
    lowers to), so XLA plans the aliasing at compile time instead of
    discovering it per call. The k/v cache operands sit at flat argument
    positions ``n_params + 2`` / ``n_params + 3`` (params, token, pos,
    k, v, q) and alias tuple outputs 4 / 5 of
    ``(logits, kl, conf, ent, k, v)`` — ``test_superstep.py`` pins both
    the alias table and result parity against the undonated lowering
    (``donate=False``, the parity tests' oracle).
    """
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    n_p = len(names)
    param_specs = [_spec(shapes[n]) for n in names]
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim

    def superstep_fn(*args):
        p = dict(zip(names, args[:n_p]))
        token, pos, kc, vc, q = args[n_p : n_p + 5]
        return superstep(cfg, p, token, pos, kc, vc, q)

    donate_argnums = (n_p + 2, n_p + 3) if donate else ()
    return jax.jit(superstep_fn, donate_argnums=donate_argnums).lower(
        *param_specs,
        _spec((b,), jnp.int32),
        _spec((), jnp.int32),
        _spec((lyr, b, h, s, dh)),
        _spec((lyr, b, h, s, dh)),
        _spec((cfg.vocab,)),
    )


def superstep_tap(cfg: ModelConfig, params: dict, token, pos, k_cache, v_cache, q_logits):
    """Tapped superstep: the fused decode→signals dispatch plus one
    **hidden-state tap row per branch** (the post-final-layernorm hidden,
    ``model.decode_step_tap``) for learned pruning probes.

    The tap is **appended** as output 6 of
    ``(logits, kl, conf, ent, k, v, tap)`` so the k/v outputs keep their
    positions 4 / 5 — the donation alias table is literally the untapped
    superstep's table, and the runtime's ``execute_b_donated(..., &[2, 3])``
    contract is unchanged. ``test_superstep_tap.py`` pins both the alias
    table and the bitwise parity of outputs 0–5 against the untapped
    artifact.
    """
    logits, tap, k_cache, v_cache = decode_step_tap(
        cfg, params, token, pos, k_cache, v_cache, use_pallas=True
    )
    kl, conf, ent = signals(logits, q_logits)
    return logits, kl, conf, ent, k_cache, v_cache, tap


def lower_superstep_tap(cfg: ModelConfig, b: int, donate: bool = True):
    """Lower the tapped superstep for bucket ``b``. Flat args and the k/v
    donation (``n_params + 2`` / ``n_params + 3`` aliasing tuple outputs
    4 / 5) are exactly ``lower_superstep``'s; the tap rides along as the
    extra, never-aliased output 6."""
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    n_p = len(names)
    param_specs = [_spec(shapes[n]) for n in names]
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim

    def superstep_fn(*args):
        p = dict(zip(names, args[:n_p]))
        token, pos, kc, vc, q = args[n_p : n_p + 5]
        return superstep_tap(cfg, p, token, pos, kc, vc, q)

    donate_argnums = (n_p + 2, n_p + 3) if donate else ()
    return jax.jit(superstep_fn, donate_argnums=donate_argnums).lower(
        *param_specs,
        _spec((b,), jnp.int32),
        _spec((), jnp.int32),
        _spec((lyr, b, h, s, dh)),
        _spec((lyr, b, h, s, dh)),
        _spec((cfg.vocab,)),
    )


def superstep_packed(cfg: ModelConfig, params: dict, token, pos, k_cache, v_cache, q_logits):
    """Cross-request packed superstep: ``decode_step_packed`` chained into
    the fused signal kernel — one dispatch serves every co-resident
    request whose branches share the bucket, each row at its own
    sequence position. Row-wise identical to the solo ``superstep``
    (``test_packed.py`` pins the parity)."""
    logits, k_cache, v_cache = decode_step_packed(cfg, params, token, pos, k_cache, v_cache)
    kl, conf, ent = signals(logits, q_logits)
    return logits, kl, conf, ent, k_cache, v_cache


def lower_decode_packed(cfg: ModelConfig, b: int, donate: bool = True):
    """Lower the packed (per-row ``pos``) decode step for bucket ``b``
    with compile-time k/v donation, mirroring ``lower_superstep``'s
    contract: flat args are (params…, token[b], pos[b], k, v); the k/v
    operands at positions ``n_params + 2`` / ``n_params + 3`` alias tuple
    outputs 1 / 2 of ``(logits, k, v)``."""
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    n_p = len(names)
    param_specs = [_spec(shapes[n]) for n in names]
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim

    def decode_fn(*args):
        p = dict(zip(names, args[:n_p]))
        token, pos, kc, vc = args[n_p : n_p + 4]
        return decode_step_packed(cfg, p, token, pos, kc, vc)

    donate_argnums = (n_p + 2, n_p + 3) if donate else ()
    return jax.jit(decode_fn, donate_argnums=donate_argnums).lower(
        *param_specs,
        _spec((b,), jnp.int32),
        _spec((b,), jnp.int32),
        _spec((lyr, b, h, s, dh)),
        _spec((lyr, b, h, s, dh)),
    )


def lower_superstep_packed(cfg: ModelConfig, b: int, donate: bool = True):
    """Lower the packed superstep for bucket ``b`` with compile-time k/v
    donation. Flat args are (params…, token[b], pos[b], k, v, q); the k/v
    operands at ``n_params + 2`` / ``n_params + 3`` alias tuple outputs
    4 / 5 of ``(logits, kl, conf, ent, k, v)`` — exactly the solo
    superstep's table (``test_packed.py`` pins it)."""
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    n_p = len(names)
    param_specs = [_spec(shapes[n]) for n in names]
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim

    def superstep_fn(*args):
        p = dict(zip(names, args[:n_p]))
        token, pos, kc, vc, q = args[n_p : n_p + 5]
        return superstep_packed(cfg, p, token, pos, kc, vc, q)

    donate_argnums = (n_p + 2, n_p + 3) if donate else ()
    return jax.jit(superstep_fn, donate_argnums=donate_argnums).lower(
        *param_specs,
        _spec((b,), jnp.int32),
        _spec((b,), jnp.int32),
        _spec((lyr, b, h, s, dh)),
        _spec((lyr, b, h, s, dh)),
        _spec((cfg.vocab,)),
    )


def superstep_tap_packed(cfg: ModelConfig, params: dict, token, pos, k_cache, v_cache, q_logits):
    """Tapped packed superstep: ``decode_step_packed_tap`` chained into
    the fused signal kernel, tap appended as output 6 — the packed
    counterpart of ``superstep_tap`` with the same unchanged k/v alias
    table."""
    logits, tap, k_cache, v_cache = decode_step_packed_tap(cfg, params, token, pos, k_cache, v_cache)
    kl, conf, ent = signals(logits, q_logits)
    return logits, kl, conf, ent, k_cache, v_cache, tap


def lower_superstep_tap_packed(cfg: ModelConfig, b: int, donate: bool = True):
    """Lower the tapped packed superstep for bucket ``b`` — flat args and
    k/v donation exactly ``lower_superstep_packed``'s, tap as the extra
    never-aliased output 6."""
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    n_p = len(names)
    param_specs = [_spec(shapes[n]) for n in names]
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim

    def superstep_fn(*args):
        p = dict(zip(names, args[:n_p]))
        token, pos, kc, vc, q = args[n_p : n_p + 5]
        return superstep_tap_packed(cfg, p, token, pos, kc, vc, q)

    donate_argnums = (n_p + 2, n_p + 3) if donate else ()
    return jax.jit(superstep_fn, donate_argnums=donate_argnums).lower(
        *param_specs,
        _spec((b,), jnp.int32),
        _spec((b,), jnp.int32),
        _spec((lyr, b, h, s, dh)),
        _spec((lyr, b, h, s, dh)),
        _spec((cfg.vocab,)),
    )


def lower_fuse(cfg: ModelConfig, b: int):
    """Lower the pod-admission row merge for bucket ``b``: args are
    (k_dst, v_dst, k_src[L,1,…], v_src, idx[b]) — see
    ``model.fuse_rows``. No parameter prefix (pure data movement, like
    the gathers)."""
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim

    def fuse_fn(kd, vd, ks, vs, idx):
        return fuse_rows(kd, vd, ks, vs, idx)

    return jax.jit(fuse_fn).lower(
        _spec((lyr, b, h, s, dh)),
        _spec((lyr, b, h, s, dh)),
        _spec((lyr, 1, h, s, dh)),
        _spec((lyr, 1, h, s, dh)),
        _spec((b,), jnp.int32),
    )


def lower_compact(cfg: ModelConfig, src_b: int, dst_b: int, donate: bool = True):
    """Lower the pod-compaction row gather ``src_b`` → ``dst_b``: args are
    (k_dst[L,D,…], v_dst, k_src[L,S,…], v_src, idx[D]) — see
    ``model.compact_rows``. The **destination** k/v (flat args 0 / 1) are
    donated and alias tuple outputs 0 / 1 — the same k/v
    ``input_output_alias`` contract the decode/superstep families carry
    for their cache operands, so XLA plans the in-place write into the
    smaller pod at compile time. No parameter prefix (pure data
    movement, like the gathers). ``test_packed.py`` pins the alias table
    and the donated-vs-undonated result parity."""
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim

    def compact_fn(kd, vd, ks, vs, idx):
        return compact_rows(kd, vd, ks, vs, idx)

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(compact_fn, donate_argnums=donate_argnums).lower(
        _spec((lyr, dst_b, h, s, dh)),
        _spec((lyr, dst_b, h, s, dh)),
        _spec((lyr, src_b, h, s, dh)),
        _spec((lyr, src_b, h, s, dh)),
        _spec((dst_b,), jnp.int32),
    )


def lower_fork(cfg: ModelConfig, src_b: int, dst_b: int, donate: bool = True):
    """Lower the prefix-sharing copy-on-write fork ``src_b`` → ``dst_b``:
    args are (k_dst[L,D,…], v_dst, k_src[L,S,…], v_src, idx[D]) — see
    ``model.fork_rows``. The **destination** k/v (flat args 0 / 1) are
    donated and alias tuple outputs 0 / 1 — the exact
    ``input_output_alias`` contract ``lower_compact`` carries — so XLA
    plans the in-place broadcast into the pod's leased rows at compile
    time. The source (the shared prefix entry) is never donated: it
    stays live in the prefix store for the next reader. No parameter
    prefix (pure data movement). ``test_fork.py`` pins the alias table,
    the donated-vs-undonated parity, and bitwise row equality against a
    per-branch solo prefill."""
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim

    def fork_fn(kd, vd, ks, vs, idx):
        return fork_rows(kd, vd, ks, vs, idx)

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(fork_fn, donate_argnums=donate_argnums).lower(
        _spec((lyr, dst_b, h, s, dh)),
        _spec((lyr, dst_b, h, s, dh)),
        _spec((lyr, src_b, h, s, dh)),
        _spec((lyr, src_b, h, s, dh)),
        _spec((dst_b,), jnp.int32),
    )


def to_hlo_text(lowered) -> str:
    """jax Lowered → XLA HLO text (the only interchange the Rust side accepts)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, name: str, text: str) -> str:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    return name


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def gather_pairs(buckets=BATCH_BUCKETS):
    """(src, dst) bucket pairs the engine needs: broadcast-from-1 after
    prefill, and shrink-compaction after pruning."""
    pairs = []
    for s in buckets:
        for d in buckets:
            if s == 1 or d <= s:
                pairs.append((s, d))
    return sorted(set(pairs))


def compact_pairs(buckets=BATCH_BUCKETS):
    """(src, dst) bucket pairs pod compaction needs: every strict shrink.
    (A same-bucket "compaction" reclaims nothing, so it is not exported —
    the engine's trigger only fires when a smaller bucket fits.)"""
    return sorted((s, d) for s in buckets for d in buckets if d < s)


def fork_pairs(buckets=BATCH_BUCKETS):
    """(src, dst) bucket pairs the prefix fork needs: a shared prefix
    entry is always a bucket-1 prefill cache, broadcast into any pod
    bucket (including bucket 1 — a solo request forking its own copy of
    the shared entry)."""
    return sorted((1, d) for d in buckets)


def export_model(cfg: ModelConfig, params: dict, out_dir: str, buckets=BATCH_BUCKETS):
    """Lower all graphs for one model size; returns manifest fragment."""
    names = cfg.param_names()
    shapes = cfg.param_shapes()
    n_p = len(names)
    param_specs = [_spec(shapes[n]) for n in names]
    lyr, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim
    arts: dict = {
        "decode": {},
        "superstep": {},
        "superstep_tap": {},
        "gather": {},
        "decode_packed": {},
        "superstep_packed": {},
        "superstep_tap_packed": {},
        "fuse": {},
        "compact": {},
        "fork": {},
    }

    def as_dict(flat):
        return dict(zip(names, flat))

    # --- prefill (b=1) ---
    def prefill_fn(*args):
        p = as_dict(args[:n_p])
        tokens, length = args[n_p], args[n_p + 1]
        return prefill(cfg, p, tokens, length)

    lowered = jax.jit(prefill_fn).lower(
        *param_specs, _spec((1, cfg.prompt_len), jnp.int32), _spec((), jnp.int32)
    )
    arts["prefill"] = _write(out_dir, f"prefill_{cfg.name}_b1.hlo.txt", to_hlo_text(lowered))

    # --- decode per bucket ---
    for b in buckets:
        def decode_fn(*args):
            p = as_dict(args[:n_p])
            token, pos, kc, vc = args[n_p : n_p + 4]
            return decode_step(cfg, p, token, pos, kc, vc, use_pallas=True)

        lowered = jax.jit(decode_fn).lower(
            *param_specs,
            _spec((b,), jnp.int32),
            _spec((), jnp.int32),
            _spec((lyr, b, h, s, dh)),
            _spec((lyr, b, h, s, dh)),
        )
        arts["decode"][str(b)] = _write(
            out_dir, f"decode_{cfg.name}_b{b}.hlo.txt", to_hlo_text(lowered)
        )

    # --- fused decode+signals superstep per bucket ---
    # Same argument prefix as decode (params, token, pos, k, v) plus the
    # device-resident q as the final input, so the Rust side reuses one
    # persistent argument table for both executables. Lowered with k/v
    # donation so the HLO carries the input_output_alias config matching
    # the runtime's execute_b_donated dispatch (see lower_superstep).
    for b in buckets:
        arts["superstep"][str(b)] = _write(
            out_dir, f"superstep_{cfg.name}_b{b}.hlo.txt", to_hlo_text(lower_superstep(cfg, b))
        )

    # --- tapped superstep per bucket (PR 8): the pluggable-signal-family
    # variant emitting one hidden-state tap row per branch as an appended
    # output 6, so k/v keep positions 4/5 and the donation alias table is
    # unchanged. Optional on the Rust side — older artifact sets without
    # it still load; the hidden-probe scorer just reports unavailable.
    for b in buckets:
        arts["superstep_tap"][str(b)] = _write(
            out_dir,
            f"superstep_tap_{cfg.name}_b{b}.hlo.txt",
            to_hlo_text(lower_superstep_tap(cfg, b)),
        )

    # --- cross-request batch fusion (PR 4): packed decode/superstep with
    # per-row positions, plus the pod-admission row merge. Same donation
    # contract as the solo superstep (k/v alias into the outputs).
    for b in buckets:
        arts["decode_packed"][str(b)] = _write(
            out_dir,
            f"decode_packed_{cfg.name}_b{b}.hlo.txt",
            to_hlo_text(lower_decode_packed(cfg, b)),
        )
        arts["superstep_packed"][str(b)] = _write(
            out_dir,
            f"superstep_packed_{cfg.name}_b{b}.hlo.txt",
            to_hlo_text(lower_superstep_packed(cfg, b)),
        )
        arts["superstep_tap_packed"][str(b)] = _write(
            out_dir,
            f"superstep_tap_packed_{cfg.name}_b{b}.hlo.txt",
            to_hlo_text(lower_superstep_tap_packed(cfg, b)),
        )
        arts["fuse"][str(b)] = _write(
            out_dir, f"fuse_{cfg.name}_b{b}.hlo.txt", to_hlo_text(lower_fuse(cfg, b))
        )

    # --- pod compaction (PR 5): gather a pod's live rows into a
    # smaller-bucket pod, destination k/v donated (in-place on device).
    for src, dst in compact_pairs(buckets):
        arts["compact"][f"{src}to{dst}"] = _write(
            out_dir,
            f"compact_{cfg.name}_b{src}to{dst}.hlo.txt",
            to_hlo_text(lower_compact(cfg, src, dst)),
        )

    # --- prefix fork (PR 7): broadcast a shared bucket-1 prefix entry
    # into a pod's leased rows, destination k/v donated (in-place on
    # device); the source entry survives for the next reader.
    for src, dst in fork_pairs(buckets):
        arts["fork"][f"{src}to{dst}"] = _write(
            out_dir,
            f"fork_{cfg.name}_b{src}to{dst}.hlo.txt",
            to_hlo_text(lower_fork(cfg, src, dst)),
        )

    # --- KV gather (broadcast / compaction) ---
    for src, dst in gather_pairs(buckets):
        def gather_fn(kc, vc, idx):
            return jnp.take(kc, idx, axis=1), jnp.take(vc, idx, axis=1)

        lowered = jax.jit(gather_fn).lower(
            _spec((lyr, src, h, s, dh)), _spec((lyr, src, h, s, dh)), _spec((dst,), jnp.int32)
        )
        arts["gather"][f"{src}to{dst}"] = _write(
            out_dir, f"gather_{cfg.name}_b{src}to{dst}.hlo.txt", to_hlo_text(lowered)
        )

    # --- weights + param table ---
    offset = 0
    table = []
    blobs = []
    for n in names:
        arr = np.asarray(params[n], np.float32)
        assert arr.shape == shapes[n], (n, arr.shape, shapes[n])
        blobs.append(arr.tobytes())
        table.append({"name": n, "shape": list(arr.shape), "offset": offset, "numel": arr.size})
        offset += arr.size
    weights_file = f"weights_{cfg.name}.bin"
    with open(os.path.join(out_dir, weights_file), "wb") as f:
        f.write(b"".join(blobs))

    return {
        "config": {
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "max_seq": cfg.max_seq,
            "prompt_len": cfg.prompt_len,
            "vocab": cfg.vocab,
            "n_params": cfg.n_params(),
        },
        "params": table,
        "weights_file": weights_file,
        "artifacts": arts,
    }


def export_signals(out_dir: str, vocab: int, buckets=BATCH_BUCKETS):
    out = {}
    for b in buckets:
        lowered = jax.jit(lambda lg, q: signals(lg, q)).lower(
            _spec((b, vocab)), _spec((vocab,))
        )
        out[str(b)] = _write(out_dir, f"signals_b{b}.hlo.txt", to_hlo_text(lowered))
    return out


def save_params_npz(params, path):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params_npz(path):
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="sm,lg")
    ap.add_argument("--steps", type=int, default=None, help="override train steps (smoke builds)")
    ap.add_argument("--corpus", type=int, default=None)
    ap.add_argument("--retrain", action="store_true", help="ignore cached params npz")
    ap.add_argument(
        "--continue-from-cache",
        action="store_true",
        help="continue training from the cached params npz for --steps more steps",
    )
    ap.add_argument("--peak-lr", type=float, default=None)
    ap.add_argument("--eval-n", type=int, default=50)
    ap.add_argument(
        "--probe-n",
        type=int,
        default=60,
        help="tapped rollouts per dataset for the pruning-probe fit (0 disables)",
    )
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()

    manifest = {
        "format_version": FORMAT_VERSION,
        "vocab": {
            "chars": tokenizer.VOCAB_CHARS,
            "vocab_size": tokenizer.VOCAB_SIZE,
            "pad": tokenizer.PAD_ID,
            "bos": tokenizer.BOS_ID,
            "eos": tokenizer.EOS_ID,
        },
        "buckets": list(BATCH_BUCKETS),
        "models": {},
        "signals": export_signals(out_dir, tokenizer.VOCAB_SIZE),
    }

    for name in args.models.split(","):
        cfg = CONFIGS[name]
        cache = os.path.join(out_dir, f"params_{name}.npz")
        if os.path.exists(cache) and args.continue_from_cache:
            print(f"[aot] continuing training for {name} from {cache}")
            params, metrics = train.train_model(
                cfg,
                steps=args.steps,
                corpus_n=args.corpus,
                peak_lr=args.peak_lr,
                init_from=load_params_npz(cache),
            )
            save_params_npz(params, cache)
        elif os.path.exists(cache) and not args.retrain:
            print(f"[aot] loading cached params for {name} from {cache}")
            params, metrics = load_params_npz(cache), {"cached": True}
        else:
            params, metrics = train.train_model(
                cfg, steps=args.steps, corpus_n=args.corpus, peak_lr=args.peak_lr
            )
            save_params_npz(params, cache)
        frag = export_model(cfg, params, out_dir)
        if args.probe_n:
            # Linear pruning probe over the tapped hidden rows (PR 8):
            # fitted on greedy tapped rollouts at build time, shipped as
            # a tiny JSON artifact the Rust HiddenProbeScorer loads.
            probe = train.fit_probe(cfg, params, n=args.probe_n)
            probe_file = f"probe_{name}.json"
            with open(os.path.join(out_dir, probe_file), "w") as f:
                json.dump(probe, f, indent=1)
            frag["artifacts"]["probe"] = probe_file
            print(
                f"[aot] {name} probe fit: rows={probe['rows']}"
                f" train_acc={probe['train_acc']:.3f}"
            )
        if args.eval_n:
            accs = {}
            for ds in ("gsm_synth", "math_synth"):
                accs[ds] = train.greedy_eval(cfg, params, ds, n=args.eval_n)
                print(f"[aot] {name} greedy acc on {ds}: {accs[ds]:.3f}")
            metrics["greedy_acc"] = accs
        frag["training"] = metrics
        manifest["models"][name] = frag

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest + artifacts to {out_dir} in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
