"""L2 model graph tests: shapes, prefill↔decode equivalence, Pallas-vs-ref
attention inside the full decode step, and teacher-forced consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import tokenizer
from compile.model import (
    BATCH_BUCKETS,
    CONFIGS,
    ModelConfig,
    decode_step,
    forward_train,
    init_params,
    prefill,
)

TINY = ModelConfig(name="tiny", d_model=32, n_layers=2, n_heads=2, max_seq=48, prompt_len=16)


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, jax.random.PRNGKey(0))


def test_param_shapes_and_count(params):
    shapes = TINY.param_shapes()
    assert set(params.keys()) == set(shapes.keys())
    for k, v in params.items():
        assert v.shape == shapes[k], k
    assert TINY.n_params() == sum(int(np.prod(v.shape)) for v in params.values())


def test_registered_configs_are_consistent():
    for cfg in CONFIGS.values():
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.vocab == tokenizer.VOCAB_SIZE
        assert cfg.prompt_len < cfg.max_seq
        assert max(BATCH_BUCKETS) >= 20  # paper needs N=20


def test_prefill_shapes(params):
    toks = jnp.zeros((1, TINY.prompt_len), jnp.int32).at[0, 0].set(tokenizer.BOS_ID)
    logits, kc, vc = prefill(TINY, params, toks, jnp.int32(1))
    assert logits.shape == (1, TINY.vocab)
    assert kc.shape == (TINY.n_layers, 1, TINY.n_heads, TINY.max_seq, TINY.head_dim)
    assert vc.shape == kc.shape


def test_decode_step_shapes(params):
    b = 4
    kc = jnp.zeros((TINY.n_layers, b, TINY.n_heads, TINY.max_seq, TINY.head_dim))
    vc = jnp.zeros_like(kc)
    logits, kc2, vc2 = decode_step(TINY, params, jnp.zeros(b, jnp.int32), jnp.int32(0), kc, vc)
    assert logits.shape == (b, TINY.vocab)
    assert kc2.shape == kc.shape and vc2.shape == vc.shape


def test_pallas_and_ref_decode_agree(params):
    b = 3
    key = jax.random.PRNGKey(1)
    kc = jax.random.normal(key, (TINY.n_layers, b, TINY.n_heads, TINY.max_seq, TINY.head_dim))
    vc = jax.random.normal(jax.random.PRNGKey(2), kc.shape)
    tok = jnp.asarray([5, 6, 7], jnp.int32)
    pos = jnp.int32(9)
    lp, kp, vp = decode_step(TINY, params, tok, pos, kc, vc, use_pallas=True)
    lr, kr, vr = decode_step(TINY, params, tok, pos, kc, vc, use_pallas=False)
    np.testing.assert_allclose(lp, lr, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(kp, kr, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(vp, vr, rtol=2e-5, atol=2e-5)


def test_prefill_then_decode_matches_teacher_forcing(params):
    """Autoregressive prefill+decode must reproduce the training-graph
    logits for the same token sequence (the KV-cache correctness test)."""
    text = "q: 1+2?\na: 3"
    ids = [tokenizer.BOS_ID] + tokenizer.encode(text)
    t = len(ids)
    full = jnp.asarray([ids], jnp.int32)

    # Teacher-forced logits at every position.
    tf_logits = forward_train(TINY, params, full)  # [1, t, V]

    # Prefill over the first p0 tokens, then decode the rest one by one.
    p0 = 5
    padded = ids[:p0] + [tokenizer.PAD_ID] * (TINY.prompt_len - p0)
    logits, kc, vc = prefill(TINY, params, jnp.asarray([padded], jnp.int32), jnp.int32(p0))
    np.testing.assert_allclose(logits[0], tf_logits[0, p0 - 1], rtol=2e-4, atol=2e-4)

    pos = p0
    for i in range(p0, t):
        tok = jnp.asarray([ids[i]], jnp.int32)
        logits, kc, vc = decode_step(TINY, params, tok, jnp.int32(pos), kc, vc)
        pos += 1
        np.testing.assert_allclose(
            logits[0], tf_logits[0, i], rtol=2e-4, atol=2e-4,
            err_msg=f"mismatch at position {i}",
        )


def test_decode_is_batch_consistent(params):
    """A branch's logits must not depend on what else is in the batch —
    the property that makes bucket compaction sound."""
    b = 4
    key = jax.random.PRNGKey(3)
    kc = jax.random.normal(key, (TINY.n_layers, b, TINY.n_heads, TINY.max_seq, TINY.head_dim))
    vc = jax.random.normal(jax.random.PRNGKey(4), kc.shape)
    tok = jnp.asarray([3, 4, 5, 6], jnp.int32)
    logits4, _, _ = decode_step(TINY, params, tok, jnp.int32(7), kc, vc)

    # Same branch 2 alone in a batch of 1.
    kc1, vc1 = kc[:, 2:3], vc[:, 2:3]
    logits1, _, _ = decode_step(TINY, params, tok[2:3], jnp.int32(7), kc1, vc1)
    np.testing.assert_allclose(logits1[0], logits4[2], rtol=2e-5, atol=2e-5)


def test_prompt_padding_is_inert(params):
    """Prefill logits at len-1 must not change with trailing PAD content."""
    ids = [tokenizer.BOS_ID] + tokenizer.encode("q: 2+2?")
    n = len(ids)
    a = ids + [tokenizer.PAD_ID] * (TINY.prompt_len - n)
    b = ids + [tokenizer.PAD_ID] * (TINY.prompt_len - n)
    b[-1] = tokenizer.encode("9")[0]  # garbage in the padding region
    la, _, _ = prefill(TINY, params, jnp.asarray([a], jnp.int32), jnp.int32(n))
    lb, _, _ = prefill(TINY, params, jnp.asarray([b], jnp.int32), jnp.int32(n))
    np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-5)
