"""Fused decode→signals superstep vs the unfused two-dispatch sequence.

The Rust engine routes gated tokens through one superstep dispatch and
trusts it to be *bit-identical* to ``decode_step`` followed by
``signals`` on the downloaded logits (the unfused differential oracle it
keeps alive). These tests pin that contract at the graph level, where it
is cheap to sweep buckets and degenerate inputs.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_superstep, superstep, to_hlo_text
from compile.kernels.signals import signals
from compile.model import CONFIGS, ModelConfig, decode_step, init_params, prefill


@pytest.fixture(scope="module")
def setup():
    cfg = CONFIGS["sm"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((1, cfg.prompt_len), jnp.int32).at[0, 0].set(1)
    _, k1, v1 = prefill(cfg, params, tokens, jnp.int32(4))
    q = jax.random.normal(jax.random.PRNGKey(9), (cfg.vocab,), jnp.float32)
    return cfg, params, k1, v1, q


def broadcast_cache(c, b):
    return jnp.repeat(c, b, axis=1)


class TestSuperstepParity:
    @pytest.mark.parametrize("b", [1, 2, 4, 8])
    def test_bit_identical_to_unfused(self, setup, b):
        cfg, params, k1, v1, q = setup
        kc, vc = broadcast_cache(k1, b), broadcast_cache(v1, b)
        token = jnp.arange(b, dtype=jnp.int32) % cfg.vocab
        pos = jnp.int32(4)

        lg_f, kl_f, conf_f, ent_f, k_f, v_f = superstep(cfg, params, token, pos, kc, vc, q)
        lg_u, k_u, v_u = decode_step(cfg, params, token, pos, kc, vc, use_pallas=True)
        kl_u, conf_u, ent_u = signals(lg_u, q)

        # Same ops in the same order on both paths → bitwise equality.
        for got, want in [
            (lg_f, lg_u), (kl_f, kl_u), (conf_f, conf_u), (ent_f, ent_u),
            (k_f, k_u), (v_f, v_u),
        ]:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_padding_rows_do_not_leak_into_live_rows(self, setup):
        # Live rows' outputs must not depend on what occupies padding
        # rows (stale branches after compaction): decode rows are
        # independent and the signal reductions are row-wise.
        cfg, params, k1, v1, q = setup
        b = 4
        kc, vc = broadcast_cache(k1, b), broadcast_cache(v1, b)
        token_a = jnp.array([3, 5, 0, 0], jnp.int32)
        token_b = jnp.array([3, 5, 7, 9], jnp.int32)  # different padding rows
        pos = jnp.int32(4)

        out_a = superstep(cfg, params, token_a, pos, kc, vc, q)
        out_b = superstep(cfg, params, token_b, pos, kc, vc, q)
        for oa, ob in zip(out_a[:4], out_b[:4]):  # logits, kl, conf, ent
            np.testing.assert_array_equal(np.asarray(oa)[:2], np.asarray(ob)[:2])

    @pytest.mark.parametrize("b", [1, 4])
    def test_exported_hlo_carries_kv_input_output_alias(self, setup, b):
        # The runtime donates k/v on every superstep dispatch
        # (execute_b_donated); the exported HLO must mirror that at
        # compile time. Outputs are (logits, kl, conf, ent, k, v) and the
        # flat argument order is (params…, token, pos, k, v, q), so the
        # alias table must map output {4} ← param n_p+2 and {5} ← n_p+3.
        cfg, *_ = setup
        n_p = len(cfg.param_names())
        hlo = to_hlo_text(lower_superstep(cfg, b))
        header = hlo.splitlines()[0]
        assert "input_output_alias=" in header, f"alias config lost: {header}"
        assert re.search(rf"\{{4\}}:\s*\({n_p + 2},", header), header
        assert re.search(rf"\{{5\}}:\s*\({n_p + 3},", header), header

    def test_donated_lowering_is_result_identical_to_undonated(self, setup):
        # Donation is a memory-planning annotation, not a semantic one:
        # the donated compiled superstep must produce bitwise-identical
        # outputs to the same lowering compiled without donation.
        cfg, params, k1, v1, q = setup
        b = 2
        kc, vc = broadcast_cache(k1, b), broadcast_cache(v1, b)
        token = jnp.arange(b, dtype=jnp.int32) % cfg.vocab
        pos = jnp.int32(4)

        names = cfg.param_names()
        flat = [params[n] for n in names]
        # Undonated oracle first: the donated call consumes kc/vc (their
        # buffers are handed to the execution and must not be reused).
        plain = lower_superstep(cfg, b, donate=False).compile()(*flat, token, pos, kc, vc, q)
        donated = lower_superstep(cfg, b).compile()(*flat, token, pos, kc, vc, q)
        assert len(donated) == len(plain) == 6
        for got, want in zip(donated, plain):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_nan_q_degrades_not_crashes(self, setup):
        # A poisoned reference distribution must produce NaN signals, not
        # an exception — the Rust side degrades NaN scores via total_cmp.
        cfg, params, k1, v1, q = setup
        bad_q = q.at[0].set(jnp.nan)
        token = jnp.zeros((1,), jnp.int32)
        lg, kl, conf, ent, _, _ = superstep(cfg, params, token, jnp.int32(4), k1, v1, bad_q)
        assert np.all(np.isfinite(np.asarray(lg)))  # decode untouched by q
        assert np.all(np.isnan(np.asarray(kl)))  # KL vs poisoned q is NaN
        # conf/entropy only involve p — they stay finite.
        assert np.all(np.isfinite(np.asarray(conf)))
        assert np.all(np.isfinite(np.asarray(ent)))
