"""Cross-request batch fusion: packed decode/superstep vs solo dispatches.

The Rust scheduler's batch fusion (PR 4) packs live branches of several
co-resident requests into one shared bucket and runs a single packed
dispatch per occupied bucket per tick. Its correctness claim is that a
packed row is **bitwise identical** to the same row decoded through that
request's solo dispatch (its own bucket, its own scalar-pos executable) —
which is what keeps the fused-scheduler path bit-identical to the
blocking driver path. These tests pin that contract at the graph level:

- row parity: rows of two requests at different prompts/positions packed
  into one bucket equal their solo decode rows (logits AND caches), with
  garbage in the free rows;
- free-row writes are harmless: a packed dispatch only touches leased
  rows' caches at their own ``pos`` slot;
- the packed superstep equals packed decode + signals bitwise;
- pod admission (``fuse_rows``) broadcasts the prefill row into exactly
  the leased rows and leaves every other row untouched;
- the exported packed HLO carries the same k/v ``input_output_alias``
  table as the solo superstep, and the donated lowering is
  result-identical to the undonated one.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import (
    compact_pairs,
    lower_compact,
    lower_decode_packed,
    lower_fuse,
    lower_superstep_packed,
    superstep_packed,
    to_hlo_text,
)
from compile.kernels.signals import signals
from compile.model import (
    BATCH_BUCKETS,
    CONFIGS,
    compact_rows,
    decode_step,
    decode_step_packed,
    fuse_rows,
    init_params,
    prefill,
)


@pytest.fixture(scope="module")
def setup():
    cfg = CONFIGS["sm"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    # Two requests with different prompts and different prompt lengths —
    # the exact shape mismatch cross-request fusion must absorb.
    tok_a = jnp.zeros((1, cfg.prompt_len), jnp.int32).at[0, 0].set(1)
    tok_b = jnp.zeros((1, cfg.prompt_len), jnp.int32).at[0, 0].set(1).at[0, 1].set(5)
    _, ka1, va1 = prefill(cfg, params, tok_a, jnp.int32(4))
    _, kb1, vb1 = prefill(cfg, params, tok_b, jnp.int32(6))
    q = jax.random.normal(jax.random.PRNGKey(9), (cfg.vocab,), jnp.float32)
    return cfg, params, (ka1, va1), (kb1, vb1), q


def bc(c, b):
    return jnp.repeat(c, b, axis=1)


def packed_pod(cfg, a, bcache, rows_a=4, rows_b=2, bucket=8, garb_seed=7):
    """Pod cache: rows [0, rows_a) = request A, [rows_a, rows_a+rows_b) =
    request B, remaining rows = garbage (freed/never-leased rows)."""
    ka, va = bc(a[0], rows_a), bc(a[1], rows_a)
    kb, vb = bc(bcache[0], rows_b), bc(bcache[1], rows_b)
    free = bucket - rows_a - rows_b
    shape = (cfg.n_layers, free, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    garb = jax.random.normal(jax.random.PRNGKey(garb_seed), shape, jnp.float32)
    kp = jnp.concatenate([ka, kb, garb], axis=1)
    vp = jnp.concatenate([va, vb, 2.0 * garb], axis=1)
    return (ka, va), (kb, vb), (kp, vp)


class TestPackedParity:
    def test_packed_rows_bitwise_equal_solo_dispatches(self, setup):
        cfg, params, a1, b1, _ = setup
        (ka, va), (kb, vb), (kp, vp) = packed_pod(cfg, a1, b1)
        ta = jnp.array([3, 5, 7, 9], jnp.int32)
        tb = jnp.array([11, 13], jnp.int32)

        # Solo oracles: request A in its own bucket-4 dispatch at pos 4,
        # request B in its own bucket-2 dispatch at pos 6.
        lg_a, ka2, va2 = decode_step(cfg, params, ta, jnp.int32(4), ka, va)
        lg_b, kb2, vb2 = decode_step(cfg, params, tb, jnp.int32(6), kb, vb)

        tok = jnp.concatenate([ta, tb, jnp.zeros((2,), jnp.int32)])
        pos = jnp.array([4, 4, 4, 4, 6, 6, 0, 0], jnp.int32)
        lg_p, kp2, vp2 = decode_step_packed(cfg, params, tok, pos, kp, vp)

        np.testing.assert_array_equal(np.asarray(lg_p)[:4], np.asarray(lg_a))
        np.testing.assert_array_equal(np.asarray(lg_p)[4:6], np.asarray(lg_b))
        np.testing.assert_array_equal(np.asarray(kp2)[:, :4], np.asarray(ka2))
        np.testing.assert_array_equal(np.asarray(kp2)[:, 4:6], np.asarray(kb2))
        np.testing.assert_array_equal(np.asarray(vp2)[:, :4], np.asarray(va2))
        np.testing.assert_array_equal(np.asarray(vp2)[:, 4:6], np.asarray(vb2))

    def test_nonparticipating_rows_only_touched_at_their_pos_slot(self, setup):
        # A leased row whose request stages no token this tick is driven
        # with PAD at its own (not-yet-written) pos: every other slot of
        # its cache row must come through the dispatch untouched.
        cfg, params, a1, b1, _ = setup
        _, _, (kp, vp) = packed_pod(cfg, a1, b1)
        tok = jnp.array([3, 5, 7, 9, 0, 0, 0, 0], jnp.int32)
        pos = jnp.array([4, 4, 4, 4, 6, 6, 0, 0], jnp.int32)
        _, kp2, vp2 = decode_step_packed(cfg, params, tok, pos, kp, vp)

        kp0, kp2 = np.asarray(kp), np.asarray(kp2)
        vp0, vp2 = np.asarray(vp), np.asarray(vp2)
        # Request B's rows (4, 5): slot 6 is clobbered, all others intact.
        mask = np.ones(cfg.max_seq, bool)
        mask[6] = False
        np.testing.assert_array_equal(kp2[:, 4:6, :, mask], kp0[:, 4:6, :, mask])
        np.testing.assert_array_equal(vp2[:, 4:6, :, mask], vp0[:, 4:6, :, mask])
        # Free rows (6, 7): only slot 0 clobbered.
        mask = np.ones(cfg.max_seq, bool)
        mask[0] = False
        np.testing.assert_array_equal(kp2[:, 6:, :, mask], kp0[:, 6:, :, mask])

    def test_uniform_pos_matches_scalar_pos_decode(self, setup):
        # Degenerate packing (one request owns the whole bucket) must
        # reproduce the solo executable exactly.
        cfg, params, a1, _, _ = setup
        ka, va = bc(a1[0], 4), bc(a1[1], 4)
        tok = jnp.array([3, 5, 7, 9], jnp.int32)
        lg_s, ks, vs = decode_step(cfg, params, tok, jnp.int32(4), ka, va)
        lg_p, kpp, vpp = decode_step_packed(
            cfg, params, tok, jnp.full((4,), 4, jnp.int32), ka, va
        )
        np.testing.assert_array_equal(np.asarray(lg_p), np.asarray(lg_s))
        np.testing.assert_array_equal(np.asarray(kpp), np.asarray(ks))
        np.testing.assert_array_equal(np.asarray(vpp), np.asarray(vs))

    def test_packed_superstep_equals_packed_decode_plus_signals(self, setup):
        cfg, params, a1, b1, q = setup
        _, _, (kp, vp) = packed_pod(cfg, a1, b1)
        tok = jnp.array([3, 5, 7, 9, 11, 13, 0, 0], jnp.int32)
        pos = jnp.array([4, 4, 4, 4, 6, 6, 0, 0], jnp.int32)

        lg_f, kl_f, conf_f, ent_f, k_f, v_f = superstep_packed(
            cfg, params, tok, pos, kp, vp, q
        )
        lg_u, k_u, v_u = decode_step_packed(cfg, params, tok, pos, kp, vp)
        kl_u, conf_u, ent_u = signals(lg_u, q)
        for got, want in [
            (lg_f, lg_u), (kl_f, kl_u), (conf_f, conf_u), (ent_f, ent_u),
            (k_f, k_u), (v_f, v_u),
        ]:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestFuseRows:
    def test_admission_broadcasts_prefill_into_leased_rows_only(self, setup):
        cfg, params, a1, b1, _ = setup
        (ka, va), _, (kp, vp) = packed_pod(cfg, a1, b1)
        # Admit a new request into rows 4 and 5 (idx < 0 ⇒ source row 0).
        idx = jnp.array([0, 1, 2, 3, -1, -1, 6, 7], jnp.int32)
        kf, vf = fuse_rows(kp, vp, b1[0], b1[1], idx)
        np.testing.assert_array_equal(np.asarray(kf)[:, :4], np.asarray(ka))
        np.testing.assert_array_equal(np.asarray(vf)[:, :4], np.asarray(va))
        for r in (4, 5):
            np.testing.assert_array_equal(np.asarray(kf)[:, r], np.asarray(b1[0])[:, 0])
            np.testing.assert_array_equal(np.asarray(vf)[:, r], np.asarray(b1[1])[:, 0])
        np.testing.assert_array_equal(np.asarray(kf)[:, 6:], np.asarray(kp)[:, 6:])

    def test_scattered_free_rows_are_supported(self, setup):
        # Leases are row *lists*, not intervals — freed rows fragment, so
        # admission must handle non-contiguous targets.
        cfg, params, a1, b1, _ = setup
        _, _, (kp, vp) = packed_pod(cfg, a1, b1)
        idx = jnp.array([0, -1, 2, -1, 4, 5, -1, 7], jnp.int32)
        kf, _ = fuse_rows(kp, vp, b1[0], b1[1], idx)
        for r in (1, 3, 6):
            np.testing.assert_array_equal(np.asarray(kf)[:, r], np.asarray(b1[0])[:, 0])
        for r in (0, 2, 4, 5, 7):
            np.testing.assert_array_equal(np.asarray(kf)[:, r], np.asarray(kp)[:, r])


class TestCompactRows:
    """Pod compaction (PR 5): live rows gathered into a smaller-bucket
    pod must be bitwise copies, and decoding them there must be bitwise
    identical to decoding them in the original pod — that is what lets
    the Rust engine reclaim pod memory mid-request without perturbing
    any request's output."""

    def small_dst(self, cfg, d, seed=3):
        shape = (cfg.n_layers, d, cfg.n_heads, cfg.max_seq, cfg.head_dim)
        g = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
        return g, 3.0 * g

    def test_live_rows_are_bitwise_copies_and_free_rows_keep_dst(self, setup):
        cfg, params, a1, b1, _ = setup
        _, _, (kp, vp) = packed_pod(cfg, a1, b1)
        kd, vd = self.small_dst(cfg, 4)
        # Live rows after pruning: pod rows 0, 2 (request A) and 4, 5
        # (request B); dst row 3 stays free (idx < 0 ⇒ keep dst row).
        idx = jnp.array([0, 2, 4, -1], jnp.int32)
        kc, vc = compact_rows(kd, vd, kp, vp, idx)
        for new_r, old_r in [(0, 0), (1, 2), (2, 4)]:
            np.testing.assert_array_equal(np.asarray(kc)[:, new_r], np.asarray(kp)[:, old_r])
            np.testing.assert_array_equal(np.asarray(vc)[:, new_r], np.asarray(vp)[:, old_r])
        np.testing.assert_array_equal(np.asarray(kc)[:, 3], np.asarray(kd)[:, 3])
        np.testing.assert_array_equal(np.asarray(vc)[:, 3], np.asarray(vd)[:, 3])

    def test_decode_after_compaction_bitwise_equals_big_pod_decode(self, setup):
        # The load-bearing claim: a request that lived through a pod
        # compaction keeps producing bitwise-identical rows. Prune the
        # bucket-8 pod down to 4 live rows, compact into a bucket-4 pod,
        # and decode the same tokens both ways.
        cfg, params, a1, b1, _ = setup
        _, _, (kp, vp) = packed_pod(cfg, a1, b1)
        live = [0, 2, 4, 5]  # A pruned to rows 0/2, B keeps rows 4/5
        toks = [3, 7, 11, 13]
        pos_of = {0: 4, 2: 4, 4: 6, 5: 6}

        # Big pod: live rows staged, freed/garbage rows silent.
        tok8 = jnp.array([toks[live.index(r)] if r in live else 0 for r in range(8)], jnp.int32)
        pos8 = jnp.array([pos_of.get(r, 0) for r in range(8)], jnp.int32)
        lg8, k8, v8 = decode_step_packed(cfg, params, tok8, pos8, kp, vp)

        # Compacted pod: the same live rows at dst rows 0..3.
        kd, vd = self.small_dst(cfg, 4)
        idx = jnp.array(live, jnp.int32)
        kc, vc = compact_rows(kd, vd, kp, vp, idx)
        tok4 = jnp.array(toks, jnp.int32)
        pos4 = jnp.array([pos_of[r] for r in live], jnp.int32)
        lg4, k4, v4 = decode_step_packed(cfg, params, tok4, pos4, kc, vc)

        for new_r, old_r in enumerate(live):
            np.testing.assert_array_equal(np.asarray(lg4)[new_r], np.asarray(lg8)[old_r])
            np.testing.assert_array_equal(np.asarray(k4)[:, new_r], np.asarray(k8)[:, old_r])
            np.testing.assert_array_equal(np.asarray(v4)[:, new_r], np.asarray(v8)[:, old_r])

    def test_compact_pairs_are_every_strict_shrink(self):
        pairs = compact_pairs()
        assert all(d < s for s, d in pairs)
        assert (max(BATCH_BUCKETS), min(BATCH_BUCKETS)) in pairs
        assert (2, 1) in pairs
        assert len(pairs) == sum(1 for s in BATCH_BUCKETS for d in BATCH_BUCKETS if d < s)

    def test_compact_hlo_carries_dst_kv_alias(self, setup):
        cfg, *_ = setup
        hlo = to_hlo_text(lower_compact(cfg, 8, 4))
        header = hlo.splitlines()[0]
        assert "input_output_alias=" in header, f"alias config lost: {header}"
        # Outputs (k, v) alias the donated destination k/v at flat args
        # 0 / 1 — the same cache-operand alias contract the
        # decode/superstep families carry.
        assert re.search(r"\{0\}:\s*\(0,", header), header
        assert re.search(r"\{1\}:\s*\(1,", header), header

    def test_donated_compact_lowering_result_identical_to_undonated(self, setup):
        cfg, params, a1, b1, _ = setup
        _, _, (kp, vp) = packed_pod(cfg, a1, b1)
        kd, vd = self.small_dst(cfg, 4)
        idx = jnp.array([0, 2, 4, 5], jnp.int32)
        want = compact_rows(kd, vd, kp, vp, idx)
        plain = lower_compact(cfg, 8, 4, donate=False).compile()(kd, vd, kp, vp, idx)
        # Last: donation deletes the kd/vd buffers.
        donated = lower_compact(cfg, 8, 4).compile()(kd, vd, kp, vp, idx)
        assert len(donated) == len(plain) == 2
        for got_d, got_p, ref in zip(donated, plain, want):
            np.testing.assert_array_equal(np.asarray(got_d), np.asarray(got_p))
            np.testing.assert_array_equal(np.asarray(got_d), np.asarray(ref))


class TestPackedExport:
    @pytest.mark.parametrize("b", [1, 4])
    def test_packed_superstep_hlo_carries_kv_alias(self, setup, b):
        cfg, *_ = setup
        n_p = len(cfg.param_names())
        hlo = to_hlo_text(lower_superstep_packed(cfg, b))
        header = hlo.splitlines()[0]
        assert "input_output_alias=" in header, f"alias config lost: {header}"
        assert re.search(rf"\{{4\}}:\s*\({n_p + 2},", header), header
        assert re.search(rf"\{{5\}}:\s*\({n_p + 3},", header), header

    @pytest.mark.parametrize("b", [1, 4])
    def test_packed_decode_hlo_carries_kv_alias(self, setup, b):
        cfg, *_ = setup
        n_p = len(cfg.param_names())
        hlo = to_hlo_text(lower_decode_packed(cfg, b))
        header = hlo.splitlines()[0]
        assert "input_output_alias=" in header, f"alias config lost: {header}"
        # Outputs are (logits, k, v): k/v at tuple slots 1/2.
        assert re.search(rf"\{{1\}}:\s*\({n_p + 2},", header), header
        assert re.search(rf"\{{2\}}:\s*\({n_p + 3},", header), header

    def test_donated_packed_lowering_result_identical_to_undonated(self, setup):
        cfg, params, a1, b1, q = setup
        _, _, (kp, vp) = packed_pod(cfg, a1, b1)
        tok = jnp.array([3, 5, 7, 9, 11, 13, 0, 0], jnp.int32)
        pos = jnp.array([4, 4, 4, 4, 6, 6, 0, 0], jnp.int32)
        flat = [params[n] for n in cfg.param_names()]
        plain = lower_superstep_packed(cfg, 8, donate=False).compile()(
            *flat, tok, pos, kp, vp, q
        )
        donated = lower_superstep_packed(cfg, 8).compile()(*flat, tok, pos, kp, vp, q)
        assert len(donated) == len(plain) == 6
        for got, want in zip(donated, plain):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fuse_lowering_compiles_and_runs(self, setup):
        cfg, params, a1, b1, _ = setup
        _, _, (kp, vp) = packed_pod(cfg, a1, b1)
        idx = jnp.array([0, 1, 2, 3, -1, -1, 6, 7], jnp.int32)
        kf, vf = lower_fuse(cfg, 8).compile()(kp, vp, b1[0], b1[1], idx)
        want_k, want_v = fuse_rows(kp, vp, b1[0], b1[1], idx)
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(want_k))
        np.testing.assert_array_equal(np.asarray(vf), np.asarray(want_v))
