"""L1 Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis-style sweeps over shapes/dtypes/magnitudes are hand-rolled via
parametrize + seeded randomness (the brief's "hypothesis sweeps the Pallas
kernel's shapes/dtypes and assert_allclose against ref").
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import decode_attention
from compile.kernels.ref import decode_attention_ref, signals_ref
from compile.kernels.signals import signals

RTOL, ATOL = 2e-5, 2e-5


def rand(key, shape, scale=1.0, dtype=jnp.float32):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


class TestSignalsKernel:
    @pytest.mark.parametrize("b", [1, 2, 3, 5, 8, 16, 32])
    @pytest.mark.parametrize("v", [8, 64])
    def test_matches_ref_across_shapes(self, b, v):
        logits = rand(b * 100 + v, (b, v), scale=3.0)
        q = rand(7, (v,), scale=2.0)
        out = signals(logits, q)
        ref = signals_ref(logits, q)
        for o, r in zip(out, ref):
            np.testing.assert_allclose(o, r, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("scale", [0.01, 1.0, 10.0, 50.0])
    def test_stable_across_magnitudes(self, scale):
        logits = rand(3, (4, 64), scale=scale)
        q = rand(4, (64,), scale=scale)
        kl, conf, ent = signals(logits, q)
        assert np.all(np.isfinite(kl))
        assert np.all(np.isfinite(conf))
        assert np.all(np.isfinite(ent))
        ref = signals_ref(logits, q)
        np.testing.assert_allclose(kl, ref[0], rtol=1e-4, atol=1e-4)

    def test_shift_invariance(self):
        # Softmax is shift-invariant: adding a constant to logits must not
        # change any signal.
        logits = rand(11, (4, 64), scale=2.0)
        q = rand(12, (64,), scale=2.0)
        a = signals(logits, q)
        b = signals(logits + 100.0, q + 50.0)
        for x, y in zip(a, b):
            np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-4)

    def test_kl_nonnegative_and_zero_on_match(self):
        q = rand(5, (64,), scale=2.0)
        logits = jnp.tile(q[None, :], (6, 1))
        kl, conf, ent = signals(logits, q)
        np.testing.assert_allclose(kl, np.zeros(6), atol=1e-5)
        # Random rows: KL ≥ 0 always.
        logits = rand(6, (16, 64), scale=3.0)
        kl, _, _ = signals(logits, q)
        assert np.all(np.asarray(kl) >= -1e-6)

    def test_confidence_bounds_and_entropy_range(self):
        logits = rand(8, (16, 64), scale=4.0)
        q = rand(9, (64,))
        _, conf, ent = signals(logits, q)
        assert np.all((np.asarray(conf) > 0) & (np.asarray(conf) <= 1.0 + 1e-6))
        assert np.all((np.asarray(ent) >= -1e-6) & (np.asarray(ent) <= np.log(64) + 1e-5))

    def test_block_padding_path(self):
        # b=5 with block_b=4 exercises the pad-and-truncate path.
        logits = rand(10, (5, 64), scale=2.0)
        q = rand(11, (64,))
        out = signals(logits, q, block_b=4)
        ref = signals_ref(logits, q)
        for o, r in zip(out, ref):
            np.testing.assert_allclose(o, r, rtol=RTOL, atol=ATOL)

    def test_random_sweep(self):
        # 20 random (b, v, scale) configurations.
        rng = np.random.default_rng(0)
        for _ in range(20):
            b = int(rng.integers(1, 33))
            v = int(rng.choice([16, 32, 64]))
            scale = float(rng.choice([0.1, 1.0, 5.0]))
            logits = rand(int(rng.integers(1e6)), (b, v), scale=scale)
            q = rand(int(rng.integers(1e6)), (v,), scale=scale)
            out = signals(logits, q)
            ref = signals_ref(logits, q)
            for o, r in zip(out, ref):
                np.testing.assert_allclose(o, r, rtol=1e-4, atol=1e-4)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,h,s,dh", [(1, 1, 8, 4), (2, 4, 32, 16), (4, 5, 224, 32), (8, 4, 64, 24)])
    def test_matches_ref(self, b, h, s, dh):
        q = rand(1, (b, h, dh))
        k = rand(2, (b, h, s, dh))
        v = rand(3, (b, h, s, dh))
        pos = s // 2
        bias = jnp.where(jnp.arange(s) <= pos, 0.0, -1e30).astype(jnp.float32)
        out = decode_attention(q, k, v, bias)
        ref = decode_attention_ref(q, k, v, pos)
        np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("pos", [0, 1, 7])
    def test_mask_positions(self, pos):
        b, h, s, dh = 2, 2, 8, 4
        q = rand(4, (b, h, dh))
        k = rand(5, (b, h, s, dh))
        v = rand(6, (b, h, s, dh))
        bias = jnp.where(jnp.arange(s) <= pos, 0.0, -1e30).astype(jnp.float32)
        out = decode_attention(q, k, v, bias)
        ref = decode_attention_ref(q, k, v, pos)
        np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)

    def test_masked_tail_is_ignored(self):
        # Garbage beyond pos must not affect the output.
        b, h, s, dh = 1, 2, 16, 8
        pos = 5
        q = rand(7, (b, h, dh))
        k = rand(8, (b, h, s, dh))
        v = rand(9, (b, h, s, dh))
        bias = jnp.where(jnp.arange(s) <= pos, 0.0, -1e30).astype(jnp.float32)
        out1 = decode_attention(q, k, v, bias)
        k2 = k.at[:, :, pos + 1 :, :].set(1e6)
        v2 = v.at[:, :, pos + 1 :, :].set(-1e6)
        out2 = decode_attention(q, k2, v2, bias)
        np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)

    def test_pos_zero_returns_first_value(self):
        # With only slot 0 visible, output == v[..., 0, :].
        b, h, s, dh = 2, 3, 8, 4
        q = rand(10, (b, h, dh))
        k = rand(11, (b, h, s, dh))
        v = rand(12, (b, h, s, dh))
        bias = jnp.where(jnp.arange(s) <= 0, 0.0, -1e30).astype(jnp.float32)
        out = decode_attention(q, k, v, bias)
        np.testing.assert_allclose(out, v[:, :, 0, :], rtol=1e-6, atol=1e-6)

    def test_random_sweep(self):
        rng = np.random.default_rng(1)
        for _ in range(10):
            b = int(rng.integers(1, 9))
            h = int(rng.choice([1, 2, 4, 5]))
            s = int(rng.choice([16, 64, 224]))
            dh = int(rng.choice([4, 24, 32]))
            pos = int(rng.integers(0, s))
            q = rand(int(rng.integers(1e6)), (b, h, dh))
            k = rand(int(rng.integers(1e6)), (b, h, s, dh))
            v = rand(int(rng.integers(1e6)), (b, h, s, dh))
            bias = jnp.where(jnp.arange(s) <= pos, 0.0, -1e30).astype(jnp.float32)
            out = decode_attention(q, k, v, bias)
            ref = decode_attention_ref(q, k, v, pos)
            np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
