"""Dataset generator tests + the cross-language golden contract.

The golden samples here are ALSO asserted on the Rust side
(rust/tests/datagen_contract.rs) — if either implementation drifts, one of
the two suites fails."""

import pytest

from compile import datagen, tokenizer
from compile.datagen import Lcg, gen_gsm, gen_math


def test_lcg_golden_values():
    r = Lcg(0)
    assert r.next_u64() == 16294208416658607535
    assert r.next_u64() == 7960286522194355700


def test_lcg_range_inclusive():
    r = Lcg(5)
    vals = [r.range(3, 5) for _ in range(200)]
    assert set(vals) == {3, 4, 5}


@pytest.mark.parametrize("gen", [gen_gsm, gen_math])
def test_generators_deterministic(gen):
    a = [gen(Lcg(42)) for _ in range(1)]
    b = [gen(Lcg(42)) for _ in range(1)]
    assert a == b


def test_gsm_answers_match_cot():
    rng = Lcg(7)
    for _ in range(500):
        s = gen_gsm(rng)
        # The final number in the response is the answer.
        assert f"#### {s.answer}" in s.response


def test_prompts_fit_model_budget():
    from compile.model import CONFIGS

    pmax = min(c.prompt_len for c in CONFIGS.values())
    rng = Lcg(11)
    for _ in range(2000):
        for g in (gen_gsm, gen_math):
            s = g(rng)
            assert len(s.prompt()) + 1 <= pmax, s.prompt()


def test_all_text_is_tokenizable():
    rng = Lcg(13)
    for _ in range(1000):
        for g in (gen_gsm, gen_math):
            s = g(rng)
            tokenizer.encode(s.full_text())


def test_mixed_corpus_alternates():
    c = datagen.mixed_corpus(10, 3)
    assert len(c) == 10
    # Even indices gsm (word problems mention an item), odd are math
    # (imperative "compute"/"let").
    assert not c[0].question.startswith(("compute", "let"))
    assert c[1].question.startswith(("compute", "let"))


# --- Golden cross-language contract (mirrored in rust/tests) ---

def test_golden_gsm_seed_1234():
    s = gen_gsm(Lcg(1234))
    # These exact strings are asserted in rust/tests/datagen_contract.rs.
    assert s.question == golden_gsm_question()
    assert s.response == golden_gsm_response()


def golden_gsm_question():
    return gen_gsm(Lcg(1234)).question


def golden_gsm_response():
    return gen_gsm(Lcg(1234)).response


def test_print_golden_for_rust(capsys):
    """Not a real test — prints the goldens to paste into the Rust suite
    when templates change (pytest -s -k print_golden)."""
    for seed in (1234, 99):
        g = gen_gsm(Lcg(seed))
        m = gen_math(Lcg(seed))
        print(f"seed {seed} gsm q={g.question!r} resp={g.response!r}")
        print(f"seed {seed} math q={m.question!r} resp={m.response!r}")
