"""Tapped superstep vs the untapped artifact (PR 8: pluggable signal
families).

The tapped superstep appends one hidden-state tap row per branch as
output 6 of ``(logits, kl, conf, ent, k, v, tap)``. The Rust engine only
enables the hidden-probe scorer when these invariants hold, and the
analytic default keeps dispatching the untapped artifact — so the whole
refactor rests on the facts pinned here:

- outputs 0–5 are **bitwise identical** to the untapped superstep (the
  tap adds an output, never perturbs the shared body);
- the tap IS the post-final-layernorm hidden the head projection reads;
- the k/v donation alias table is literally the untapped one
  ({4} ← n_p+2, {5} ← n_p+3) and the tap output is never aliased.
"""

import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train
from compile.aot import (
    lower_superstep_tap,
    superstep,
    superstep_packed,
    superstep_tap,
    superstep_tap_packed,
    to_hlo_text,
)
from compile.model import CONFIGS, _decode_body, decode_step, decode_step_tap, init_params, prefill


@pytest.fixture(scope="module")
def setup():
    cfg = CONFIGS["sm"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((1, cfg.prompt_len), jnp.int32).at[0, 0].set(1)
    _, k1, v1 = prefill(cfg, params, tokens, jnp.int32(4))
    q = jax.random.normal(jax.random.PRNGKey(9), (cfg.vocab,), jnp.float32)
    return cfg, params, k1, v1, q


def broadcast_cache(c, b):
    return jnp.repeat(c, b, axis=1)


class TestSuperstepTapParity:
    @pytest.mark.parametrize("b", [1, 2, 4, 8])
    def test_outputs_bitwise_identical_to_untapped(self, setup, b):
        # The contract the analytic bit-identity rail rests on: enabling
        # the tap family must not change a single bit of the logits, the
        # three signal rows, or the caches.
        cfg, params, k1, v1, q = setup
        kc, vc = broadcast_cache(k1, b), broadcast_cache(v1, b)
        token = jnp.arange(b, dtype=jnp.int32) % cfg.vocab
        pos = jnp.int32(4)

        tapped = superstep_tap(cfg, params, token, pos, kc, vc, q)
        plain = superstep(cfg, params, token, pos, kc, vc, q)
        assert len(tapped) == 7 and len(plain) == 6
        for got, want in zip(tapped[:6], plain):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert tapped[6].shape == (b, cfg.d_model)

    def test_tap_is_the_post_lnf_hidden(self, setup):
        # The tap row must be exactly the intermediate the head
        # projection consumes — the shared `_decode_body` output — not a
        # re-derived or re-normalized copy.
        cfg, params, k1, v1, q = setup
        token = jnp.zeros((1,), jnp.int32)
        pos = jnp.int32(4)

        logits_t, tap, k_t, v_t = decode_step_tap(cfg, params, token, pos, k1, v1)
        hidden, k_b, v_b = _decode_body(cfg, params, token, pos, k1, v1)
        np.testing.assert_array_equal(np.asarray(tap), np.asarray(hidden))
        logits_u, k_u, v_u = decode_step(cfg, params, token, pos, k1, v1)
        np.testing.assert_array_equal(np.asarray(logits_t), np.asarray(logits_u))
        np.testing.assert_array_equal(np.asarray(k_t), np.asarray(k_u))
        np.testing.assert_array_equal(np.asarray(v_t), np.asarray(v_u))

    @pytest.mark.parametrize("b", [1, 4])
    def test_alias_table_unchanged_and_tap_never_aliased(self, setup, b):
        # Outputs are (logits, kl, conf, ent, k, v, tap): k/v keep tuple
        # positions 4/5, so the alias table must be the untapped
        # superstep's ({4} ← n_p+2, {5} ← n_p+3) and the appended tap
        # output {6} must not alias any donated operand.
        cfg, *_ = setup
        n_p = len(cfg.param_names())
        hlo = to_hlo_text(lower_superstep_tap(cfg, b))
        header = hlo.splitlines()[0]
        assert "input_output_alias=" in header, f"alias config lost: {header}"
        assert re.search(rf"\{{4\}}:\s*\({n_p + 2},", header), header
        assert re.search(rf"\{{5\}}:\s*\({n_p + 3},", header), header
        assert not re.search(r"\{6\}:", header), f"tap output aliased: {header}"

    def test_donated_lowering_is_result_identical_to_undonated(self, setup):
        cfg, params, k1, v1, q = setup
        b = 2
        kc, vc = broadcast_cache(k1, b), broadcast_cache(v1, b)
        token = jnp.arange(b, dtype=jnp.int32) % cfg.vocab
        pos = jnp.int32(4)

        names = cfg.param_names()
        flat = [params[n] for n in names]
        # Undonated oracle first: the donated call consumes kc/vc.
        plain = lower_superstep_tap(cfg, b, donate=False).compile()(*flat, token, pos, kc, vc, q)
        donated = lower_superstep_tap(cfg, b).compile()(*flat, token, pos, kc, vc, q)
        assert len(donated) == len(plain) == 7
        for got, want in zip(donated, plain):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("b", [2, 4])
    def test_packed_outputs_bitwise_identical_to_untapped_packed(self, setup, b):
        cfg, params, k1, v1, q = setup
        kc, vc = broadcast_cache(k1, b), broadcast_cache(v1, b)
        token = jnp.arange(b, dtype=jnp.int32) % cfg.vocab
        pos = jnp.full((b,), 4, jnp.int32)

        tapped = superstep_tap_packed(cfg, params, token, pos, kc, vc, q)
        plain = superstep_packed(cfg, params, token, pos, kc, vc, q)
        assert len(tapped) == 7 and len(plain) == 6
        for got, want in zip(tapped[:6], plain):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_packed_tap_rows_match_solo_tap_rows(self, setup):
        # Same per-row position → the packed tap row is bitwise the solo
        # tap row, the same lockstep parity the packed decode pins.
        cfg, params, k1, v1, q = setup
        b = 2
        kc, vc = broadcast_cache(k1, b), broadcast_cache(v1, b)
        token = jnp.arange(b, dtype=jnp.int32) % cfg.vocab

        tap_solo = superstep_tap(cfg, params, token, jnp.int32(4), kc, vc, q)[6]
        tap_packed = superstep_tap_packed(
            cfg, params, token, jnp.full((b,), 4, jnp.int32), kc, vc, q
        )[6]
        np.testing.assert_array_equal(np.asarray(tap_packed), np.asarray(tap_solo))


class TestProbeFit:
    def test_fit_probe_smoke_and_json_round_trip(self, setup):
        # Build-time probe fitting must produce a well-formed, finite,
        # JSON-serializable artifact even on a tiny rollout budget.
        cfg, params, *_ = setup
        probe = train.fit_probe(cfg, params, n=3, steps=40, max_new=6)
        assert probe["d_model"] == cfg.d_model
        assert len(probe["w"]) == cfg.d_model
        assert np.all(np.isfinite(np.asarray(probe["w"])))
        assert np.isfinite(probe["b"])
        assert probe["rows"] >= 0
        assert 0.0 <= probe["train_acc"] <= 1.0
        loaded = json.loads(json.dumps(probe))
        assert loaded["w"] == probe["w"] and loaded["b"] == probe["b"]
