"""Prompt-prefix KV sharing: the ``fork`` artifact family (PR 7).

The Rust engine's prefix store prefills each unique token prefix **once**
into a shared bucket-1 entry and admits later readers by *forking* that
entry into their pod rows — copy-on-write at the divergence point. The
correctness claims pinned here at the graph level:

- a forked row is **bitwise identical** to the row a per-branch solo
  prefill would have produced (fork-from-shared-entry ≡ cold prefill);
- fork writes exactly the selected rows and leaves every other pod row
  untouched (resident requests are invisible to an admission fork);
- decode after a fork is bitwise identical to decode after the existing
  gather-broadcast admission — the fused scheduler may use either
  dispatch for the same request without perturbing its output;
- the source (shared) entry operands are never donated: the exported
  HLO's ``input_output_alias`` table aliases outputs 0/1 to the
  **destination** k/v at flat args 0/1 only (the ``compact`` contract),
  and the donated lowering is result-identical to the undonated one.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import fork_pairs, lower_fork, to_hlo_text
from compile.model import (
    BATCH_BUCKETS,
    CONFIGS,
    decode_step_packed,
    fork_rows,
    fuse_rows,
    init_params,
    prefill,
)


@pytest.fixture(scope="module")
def setup():
    cfg = CONFIGS["sm"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    # One shared prefix (the store entry) and one distinct resident
    # request already living in the pod.
    tok_p = jnp.zeros((1, cfg.prompt_len), jnp.int32).at[0, 0].set(1).at[0, 1].set(3)
    tok_r = jnp.zeros((1, cfg.prompt_len), jnp.int32).at[0, 0].set(1).at[0, 1].set(5)
    _, kp1, vp1 = prefill(cfg, params, tok_p, jnp.int32(5))
    _, kr1, vr1 = prefill(cfg, params, tok_r, jnp.int32(6))
    return cfg, params, tok_p, (kp1, vp1), (kr1, vr1)


def pod_with_resident(cfg, resident, rows_r=2, bucket=8, garb_seed=11):
    """Bucket-``bucket`` pod: rows [0, rows_r) hold ``resident``'s
    branches, the rest is garbage (free rows)."""
    kr = jnp.repeat(resident[0], rows_r, axis=1)
    vr = jnp.repeat(resident[1], rows_r, axis=1)
    shape = (cfg.n_layers, bucket - rows_r, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    garb = jax.random.normal(jax.random.PRNGKey(garb_seed), shape, jnp.float32)
    kp = jnp.concatenate([kr, garb], axis=1)
    vp = jnp.concatenate([vr, 2.0 * garb], axis=1)
    return (kr, vr), (kp, vp)


class TestForkRows:
    def test_forked_rows_bitwise_equal_cold_prefill(self, setup):
        # The tentpole claim: admitting from the shared entry produces
        # rows bitwise equal to what a per-branch solo prefill would
        # have produced (the entry IS a cold prefill's cache, and fork
        # must copy it exactly).
        cfg, params, tok_p, entry, resident = setup
        _, (kp, vp) = pod_with_resident(cfg, resident)
        cold_k, cold_v = prefill(cfg, params, tok_p, jnp.int32(5))[1:]
        idx = jnp.array([-1, -1, 0, 0, 0, -1, -1, -1], jnp.int32)
        kf, vf = fork_rows(kp, vp, entry[0], entry[1], idx)
        for r in (2, 3, 4):
            np.testing.assert_array_equal(np.asarray(kf)[:, r], np.asarray(cold_k)[:, 0])
            np.testing.assert_array_equal(np.asarray(vf)[:, r], np.asarray(cold_v)[:, 0])

    def test_fork_leaves_unselected_rows_untouched(self, setup):
        # Resident rows (0, 1) and free rows (5..7) must come through
        # the fork dispatch bitwise intact — an admission is invisible
        # to every co-resident request.
        cfg, params, _, entry, resident = setup
        (kr, vr), (kp, vp) = pod_with_resident(cfg, resident)
        idx = jnp.array([-1, -1, 0, 0, 0, -1, -1, -1], jnp.int32)
        kf, vf = fork_rows(kp, vp, entry[0], entry[1], idx)
        np.testing.assert_array_equal(np.asarray(kf)[:, :2], np.asarray(kr))
        np.testing.assert_array_equal(np.asarray(vf)[:, :2], np.asarray(vr))
        np.testing.assert_array_equal(np.asarray(kf)[:, 5:], np.asarray(kp)[:, 5:])
        np.testing.assert_array_equal(np.asarray(vf)[:, 5:], np.asarray(vp)[:, 5:])

    def test_scattered_lease_rows_are_supported(self, setup):
        # Leases are row lists, not intervals.
        cfg, params, _, entry, resident = setup
        _, (kp, vp) = pod_with_resident(cfg, resident)
        idx = jnp.array([-1, 0, -1, 0, -1, -1, 0, -1], jnp.int32)
        kf, _ = fork_rows(kp, vp, entry[0], entry[1], idx)
        for r in (1, 3, 6):
            np.testing.assert_array_equal(np.asarray(kf)[:, r], np.asarray(entry[0])[:, 0])
        for r in (0, 2, 4, 5, 7):
            np.testing.assert_array_equal(np.asarray(kf)[:, r], np.asarray(kp)[:, r])

    def test_fork_equals_fuse_for_the_same_admission(self, setup):
        # fork (select-src convention, dst donated) and fuse (keep-dst
        # convention, nothing donated) are two dispatches for the same
        # admission; the engine falls back to fuse when fork artifacts
        # are absent, so the results must be bitwise identical.
        cfg, params, _, entry, resident = setup
        _, (kp, vp) = pod_with_resident(cfg, resident)
        fork_idx = jnp.array([-1, -1, 0, 0, 0, -1, -1, -1], jnp.int32)
        fuse_idx = jnp.array([0, 1, -1, -1, -1, 5, 6, 7], jnp.int32)
        k_fork, v_fork = fork_rows(kp, vp, entry[0], entry[1], fork_idx)
        k_fuse, v_fuse = fuse_rows(kp, vp, entry[0], entry[1], fuse_idx)
        np.testing.assert_array_equal(np.asarray(k_fork), np.asarray(k_fuse))
        np.testing.assert_array_equal(np.asarray(v_fork), np.asarray(v_fuse))

    def test_decode_after_fork_bitwise_equals_decode_after_broadcast(self, setup):
        # The divergence point: the first decode after admission. Rows
        # admitted by fork must decode bitwise identically to rows
        # admitted by the gather broadcast (the no-sharing path), which
        # is what makes a prefix-store hit invisible in the output.
        cfg, params, _, entry, resident = setup
        _, (kp, vp) = pod_with_resident(cfg, resident)
        idx = jnp.array([-1, -1, 0, 0, 0, -1, -1, -1], jnp.int32)
        kf, vf = fork_rows(kp, vp, entry[0], entry[1], idx)
        # Broadcast admission: the same rows filled via jnp.take (the
        # gather executable's graph).
        sel = jnp.array([0, 0, 0], jnp.int32)
        kb = kp.at[:, 2:5].set(jnp.take(entry[0], sel, axis=1))
        vb = vp.at[:, 2:5].set(jnp.take(entry[1], sel, axis=1))
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(kb))

        tok = jnp.array([0, 0, 9, 13, 17, 0, 0, 0], jnp.int32)
        pos = jnp.array([6, 6, 5, 5, 5, 0, 0, 0], jnp.int32)
        lg_f, k_f, v_f = decode_step_packed(cfg, params, tok, pos, kf, vf)
        lg_b, k_b, v_b = decode_step_packed(cfg, params, tok, pos, kb, vb)
        np.testing.assert_array_equal(np.asarray(lg_f), np.asarray(lg_b))
        np.testing.assert_array_equal(np.asarray(k_f), np.asarray(k_b))
        np.testing.assert_array_equal(np.asarray(v_f), np.asarray(v_b))

    def test_fork_pairs_broadcast_from_one_into_every_bucket(self):
        pairs = fork_pairs()
        assert pairs == sorted((1, d) for d in BATCH_BUCKETS)


class TestForkExport:
    def test_fork_hlo_carries_dst_kv_alias_only(self, setup):
        cfg, *_ = setup
        hlo = to_hlo_text(lower_fork(cfg, 1, 8))
        header = hlo.splitlines()[0]
        assert "input_output_alias=" in header, f"alias config lost: {header}"
        # Outputs (k, v) alias the donated destination k/v at flat args
        # 0 / 1 — and the source entry (flat args 2 / 3) must never
        # appear as an alias target: the store keeps it for the next
        # reader.
        assert re.search(r"\{0\}:\s*\(0,", header), header
        assert re.search(r"\{1\}:\s*\(1,", header), header
        assert not re.search(r"\(2,", header), header
        assert not re.search(r"\(3,", header), header

    def test_donated_fork_lowering_result_identical_to_undonated(self, setup):
        cfg, params, _, entry, resident = setup
        _, (kp, vp) = pod_with_resident(cfg, resident)
        idx = jnp.array([-1, -1, 0, 0, 0, -1, -1, -1], jnp.int32)
        want = fork_rows(kp, vp, entry[0], entry[1], idx)
        plain = lower_fork(cfg, 1, 8, donate=False).compile()(kp, vp, entry[0], entry[1], idx)
        # Last: donation deletes the kp/vp buffers.
        donated = lower_fork(cfg, 1, 8).compile()(kp, vp, entry[0], entry[1], idx)
        assert len(donated) == len(plain) == 2
        for got_d, got_p, ref in zip(donated, plain, want):
            np.testing.assert_array_equal(np.asarray(got_d), np.asarray(got_p))
            np.testing.assert_array_equal(np.asarray(got_d), np.asarray(ref))

    def test_source_entry_survives_a_donated_fork(self, setup):
        # The load-bearing sharing property at the buffer level: after a
        # donated fork dispatch the source arrays are still readable and
        # unchanged (only dst was donated), so the store entry can serve
        # the next reader.
        cfg, params, _, entry, resident = setup
        _, (kp, vp) = pod_with_resident(cfg, resident)
        ks = jnp.array(np.asarray(entry[0]))
        vs = jnp.array(np.asarray(entry[1]))
        want_k = np.asarray(ks).copy()
        idx = jnp.array([-1, -1, 0, 0, 0, -1, -1, -1], jnp.int32)
        lower_fork(cfg, 1, 8).compile()(kp, vp, ks, vs, idx)
        np.testing.assert_array_equal(np.asarray(ks), want_k)
