"""Double-buffered slab staging: epoch-parity banks vs synchronous reads.

The Rust runtime's overlapped scheduler tick (PR 9) downloads epoch T's
logits slab into a caller-owned staging bank while epoch T+1's dispatch
is already in flight; the two banks alternate by epoch parity and the
pod's epoch window admits exactly two in-flight epochs. ``EpochStaging``
below is the python model of that discipline (the Rust ``StagingPair``
plus the two-deep window check in ``absorb_rows``), driven with real
decode slabs so the parity claim is about actual kernel output, not toy
data:

- a pipelined consumer running one epoch behind the producer sees every
  slab bitwise identical to a synchronous single-buffer reference;
- both in-flight epochs are readable at once (the two-deep window);
- a three-deep pull — the bank was re-tagged by epoch T+2 before epoch
  T was read — is rejected with an error naming both epochs, never
  silently served from the wrong bank.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import CONFIGS, decode_step, init_params, prefill


class StaleEpochError(Exception):
    pass


class EpochStaging:
    """Two staging banks keyed by epoch parity, tagged with the epoch
    that last wrote them. ``push`` is the download landing at issue
    order; ``pull`` is the demand-driven read and must fail loudly when
    the wanted epoch's bank has already been re-tagged by a deeper
    write (the stale three-deep pull)."""

    def __init__(self):
        self.banks = [None, None]  # parity slot -> (epoch, slab)

    def push(self, epoch, slab):
        self.banks[epoch % 2] = (epoch, np.asarray(slab).copy())

    def pull(self, epoch):
        held = self.banks[epoch % 2]
        if held is None or held[0] != epoch:
            have = "empty" if held is None else held[0]
            raise StaleEpochError(
                f"stale slab pull: bank {epoch % 2} holds epoch {have}, "
                f"wanted epoch {epoch}"
            )
        return held[1]


@pytest.fixture(scope="module")
def setup():
    cfg = CONFIGS["sm"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = jnp.zeros((1, cfg.prompt_len), jnp.int32).at[0, 0].set(1).at[0, 1].set(5)
    _, k1, v1 = prefill(cfg, params, tok, jnp.int32(4))
    return cfg, params, (k1, v1)


def decode_trace(cfg, params, cache, steps):
    """One-row decode chain: yields (epoch, logits slab) per step, the
    producer side of both the synchronous and the pipelined runs."""
    k, v = cache
    pos, tok = 4, jnp.array([3], jnp.int32)
    for epoch in range(steps):
        lg, k, v = decode_step(cfg, params, tok, jnp.int32(pos), k, v)
        yield epoch, lg
        tok = jnp.array([int(jnp.argmax(lg[0])) % cfg.vocab], jnp.int32)
        pos = min(pos + 1, cfg.max_seq - 1)


class TestDoubleBufferParity:
    def test_pipelined_reads_bitwise_equal_synchronous_reference(self, setup):
        cfg, params, cache = setup
        steps = 6

        # Synchronous reference: one buffer, read immediately.
        sync = [np.asarray(lg) for _, lg in decode_trace(cfg, params, cache, steps)]

        # Pipelined consumer: epoch T's slab is pulled only after epoch
        # T+1's download has landed in the other bank — exactly the
        # overlap window the Rust tick runs (download T while T+1
        # decodes) — then the final epoch drains at the boundary.
        staging = EpochStaging()
        piped = [None] * steps
        for epoch, lg in decode_trace(cfg, params, cache, steps):
            staging.push(epoch, lg)
            if epoch > 0:
                piped[epoch - 1] = staging.pull(epoch - 1)
        piped[steps - 1] = staging.pull(steps - 1)

        for e, (got, want) in enumerate(zip(piped, sync)):
            np.testing.assert_array_equal(got, want, err_msg=f"epoch {e}")

    def test_two_in_flight_epochs_are_both_readable(self, setup):
        cfg, params, cache = setup
        staging = EpochStaging()
        slabs = {e: np.asarray(lg) for e, lg in decode_trace(cfg, params, cache, 2)}
        staging.push(0, slabs[0])
        staging.push(1, slabs[1])
        # The two-deep window: both epochs resident, either pull order.
        np.testing.assert_array_equal(staging.pull(1), slabs[1])
        np.testing.assert_array_equal(staging.pull(0), slabs[0])

    def test_three_deep_pull_is_rejected_naming_both_epochs(self, setup):
        cfg, params, cache = setup
        staging = EpochStaging()
        for e, lg in decode_trace(cfg, params, cache, 3):
            staging.push(e, lg)
        # Epoch 2 re-tagged epoch 0's parity bank: the stale pull must
        # fail loudly and the error must name both epochs.
        with pytest.raises(StaleEpochError) as err:
            staging.pull(0)
        assert "epoch 2" in str(err.value) and "epoch 0" in str(err.value)
        # The in-window epochs are still served.
        assert staging.pull(1) is not None
        assert staging.pull(2) is not None

    def test_deeper_write_never_disturbs_the_other_bank(self, setup):
        cfg, params, cache = setup
        staging = EpochStaging()
        slabs = {e: np.asarray(lg) for e, lg in decode_trace(cfg, params, cache, 3)}
        staging.push(0, slabs[0])
        staging.push(1, slabs[1])
        before = staging.pull(1).copy()
        staging.push(2, slabs[2])  # overwrites bank 0, must not touch bank 1
        np.testing.assert_array_equal(staging.pull(1), before)
